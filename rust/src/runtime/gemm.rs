//! Blocked, SIMD-dispatched GEMM kernels for the pure-Rust runtime.
//!
//! The batched draft/verify paths funnel every projection (`[B,D]×[D,N]`,
//! weights row-major `[in, out]`) and — via the prepacked `[D, V]` head
//! panel ([`crate::params::PackedWeights`]) — the weight-tied logits head
//! through [`matmul`]/[`matmul_dense`], so all `c` candidate rows — or all
//! `G` teacher-forced feed positions — share one streaming pass over each
//! weight matrix instead of `B` scalar mat-vecs.
//!
//! # Kernel tiers
//!
//! Both entry points dispatch once per process ([`super::simd::active`]):
//! an explicit AVX2 arm (register-tiled 4-row × 16-column micro-kernel,
//! separate mul + add — never FMA on the default tier) when the CPU
//! supports it, and a portable chunked-lane arm that is the same code path
//! on every architecture. `SPECMER_FORCE_PORTABLE` pins the portable arm
//! for CI. The seed scalar kernels are kept verbatim ([`matmul_scalar`],
//! [`matmul_dense_scalar`], [`matmul_nt`]) as the equivalence oracle and
//! bench baseline.
//!
//! On top of the arm dispatch sit two orthogonal tiers, both reached
//! through [`matmul_panel`] (which takes a dtype-tagged
//! [`crate::params::PanelRef`] instead of an f32 slice):
//!
//!   * **Weight dtype** (`SPECMER_WEIGHT_DTYPE`): narrow panels (bf16 /
//!     f16 / int8 + per-row scales) are dequantized **in registers**
//!     inside the inner loop — shift-widen for bf16, `_mm256_cvtph_ps`
//!     (F16C) for f16, `cvtepi8` widening with the per-`k`-row scale
//!     folded into the broadcast input for int8 — so narrow weights never
//!     touch memory as f32. Accumulation stays f32. Since bf16/f16 dequant
//!     is exact and both arms keep the per-element order and separate
//!     mul + add, the AVX2 arm, the portable arm, and a
//!     dequantize-then-f32 oracle stay bitwise-equal to each other for a
//!     fixed dtype (`tests/quantization.rs`); accuracy vs the f32 tier is
//!     a property of quantization, bounded end to end in
//!     `tests/fast_tier.rs`.
//!   * **Fast tier** (`SPECMER_FAST`): the AVX2 micro-kernel switches to
//!     `_mm256_fmadd_ps` (when the FMA feature is present), rounding once
//!     per multiply-accumulate instead of twice — off the bitwise
//!     contract, validated by accuracy bounds only. The portable arm keeps
//!     separate mul + add even on the fast tier (portable `mul_add`
//!     without hardware FMA is a slow libm call, the opposite of fast).
//!
//! # Properties the rest of the runtime relies on
//!
//!   * **Bitwise-stable accumulation (default tier).** With f32 panels and
//!     the fast tier off, each output element accumulates over the shared
//!     `k` dimension strictly in index order with a single accumulator,
//!     exactly like the seed scalar mat-vec (including its skip of zero
//!     inputs; the `_dense` variants match the seed logits head, which has
//!     no skip). Vector lanes run across *independent output columns* and
//!     every multiply-accumulate is a separate IEEE mul then add, so all
//!     arms — and row partitioning across threads — are bit-identical to
//!     the per-position reference path.
//!     `tests/cpu_batched_equivalence.rs` and `tests/kernel_equivalence.rs`
//!     assert this.
//!   * **Bounded threading.** Row-parallelism (via
//!     [`crate::util::threadpool::parallel_chunks_mut`], running on the
//!     persistent [`crate::util::threadpool::compute_pool`] rather than
//!     per-call thread spawns) only kicks in past a FLOP threshold, so tiny
//!     test models never pay threading overhead. The thread budget is
//!     resolved once per process (`SPECMER_THREADS` overrides it).

use super::simd::{self, Kernel};
use crate::params::PanelRef;
use crate::util::threadpool::{compute_threads, parallel_chunks_mut};

/// 2·m·k·n below this runs single-threaded (pool handoff ≫ work).
const PAR_FLOPS: usize = 1 << 22;

/// Threads worth engaging for an `m × k × n` product.
fn plan_threads(m: usize, k: usize, n: usize) -> usize {
    if 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n) < PAR_FLOPS {
        1
    } else {
        compute_threads().min(m)
    }
}

/// `out[m,n] = a[m,k] × b[k,n]`, `b` row-major `[k,n]` (projection weights),
/// with the seed mat-vec's skip of exactly-zero inputs. Overwrites `out`.
/// Rows are partitioned across the persistent compute pool for large shapes.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let threads = plan_threads(m, k, n);
    if threads <= 1 {
        rows_dispatch(simd::active(), a, b, k, n, out, true);
        return;
    }
    let rows_per = (m + threads - 1) / threads;
    parallel_chunks_mut(out, rows_per * n, |ci, chunk| {
        let r0 = ci * rows_per;
        let rows = chunk.len() / n;
        rows_dispatch(simd::active(), &a[r0 * k..(r0 + rows) * k], b, k, n, chunk, true);
    });
}

/// [`matmul`] without the zero-input skip: accumulation per element matches
/// the seed weight-tied logits head (a plain dot product over `k`). Used
/// with the prepacked `[D, V]` embedding panel.
pub fn matmul_dense(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let threads = plan_threads(m, k, n);
    if threads <= 1 {
        rows_dispatch(simd::active(), a, b, k, n, out, false);
        return;
    }
    let rows_per = (m + threads - 1) / threads;
    parallel_chunks_mut(out, rows_per * n, |ci, chunk| {
        let r0 = ci * rows_per;
        let rows = chunk.len() / n;
        rows_dispatch(simd::active(), &a[r0 * k..(r0 + rows) * k], b, k, n, chunk, false);
    });
}

/// Single-threaded [`matmul`] on the active kernel arm (benches).
pub fn matmul_st(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    matmul_st_with(simd::active(), a, b, m, k, n, out)
}

/// Single-threaded [`matmul`] on an explicit kernel arm (tests compare the
/// arms bitwise; an AVX2 request on a machine without it runs portable).
pub fn matmul_st_with(
    kernel: Kernel,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    rows_dispatch(kernel, a, b, k, n, out, true);
}

/// Single-threaded [`matmul_dense`] on the active kernel arm (benches).
pub fn matmul_dense_st(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    matmul_dense_st_with(simd::active(), a, b, m, k, n, out)
}

/// Single-threaded [`matmul_dense`] on an explicit kernel arm.
pub fn matmul_dense_st_with(
    kernel: Kernel,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    rows_dispatch(kernel, a, b, k, n, out, false);
}

/// `out[m,n] = a[m,k] × panel[k,n]` over a dtype-tagged weight panel, with
/// fused dequant-in-register for narrow dtypes (see module docs). `skip`
/// selects the seed mat-vec's zero-input skip ([`matmul`] semantics) vs
/// the dense logits-head accumulation ([`matmul_dense`] semantics); `fast`
/// enables the FMA micro-kernel on the AVX2 arm. With an f32 panel and
/// `fast` off this routes through [`matmul`]/[`matmul_dense`] unchanged —
/// byte-identical to the pre-panel hot path, threading included.
#[allow(clippy::too_many_arguments)]
pub fn matmul_panel(
    a: &[f32],
    b: PanelRef<'_>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    skip: bool,
    fast: bool,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if let PanelRef::F32(w) = b {
        if !fast {
            if skip {
                return matmul(a, w, m, k, n, out);
            }
            return matmul_dense(a, w, m, k, n, out);
        }
    }
    let threads = plan_threads(m, k, n);
    if threads <= 1 {
        rows_dispatch_panel(simd::active(), a, b, k, n, out, skip, fast);
        return;
    }
    let rows_per = (m + threads - 1) / threads;
    parallel_chunks_mut(out, rows_per * n, |ci, chunk| {
        let r0 = ci * rows_per;
        let rows = chunk.len() / n;
        rows_dispatch_panel(
            simd::active(),
            &a[r0 * k..(r0 + rows) * k],
            b,
            k,
            n,
            chunk,
            skip,
            fast,
        );
    });
}

/// Single-threaded [`matmul_panel`] on an explicit kernel arm (the
/// cross-arm bitwise pins in `tests/quantization.rs` compare these).
#[allow(clippy::too_many_arguments)]
pub fn matmul_panel_st_with(
    kernel: Kernel,
    a: &[f32],
    b: PanelRef<'_>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    skip: bool,
    fast: bool,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    rows_dispatch_panel(kernel, a, b, k, n, out, skip, fast);
}

/// Row-block dispatch over a dtype-tagged panel. Narrow dtypes get fused
/// dequant kernels on each arm; f16 additionally needs the F16C feature on
/// the AVX2 arm (falls back to the portable dequant loop without it).
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
fn rows_dispatch_panel(
    kernel: Kernel,
    a: &[f32],
    b: PanelRef<'_>,
    k: usize,
    n: usize,
    out: &mut [f32],
    skip: bool,
    fast: bool,
) {
    let on_avx2 = kernel == Kernel::Avx2 && simd::has_avx2();
    let fma = fast && simd::has_fma();
    match b {
        PanelRef::F32(w) => {
            if on_avx2 {
                // SAFETY: AVX2 (and FMA where taken) confirmed at runtime.
                unsafe {
                    if fma {
                        avx2::rows_f32_fma(a, w, k, n, out, skip)
                    } else {
                        avx2::matmul_rows(a, w, k, n, out, skip)
                    }
                }
            } else {
                portable::matmul_rows(a, w, k, n, out, skip)
            }
        }
        PanelRef::Bf16(w) => {
            if on_avx2 {
                // SAFETY: AVX2 (and FMA where taken) confirmed at runtime.
                unsafe {
                    if fma {
                        avx2::rows_bf16_fma(a, w, k, n, out, skip)
                    } else {
                        avx2::rows_bf16(a, w, k, n, out, skip)
                    }
                }
            } else {
                portable::rows_u16(a, w, k, n, out, skip, crate::params::bf16_to_f32)
            }
        }
        PanelRef::F16(w) => {
            if on_avx2 && simd::has_f16c() {
                // SAFETY: AVX2 + F16C (and FMA where taken) confirmed.
                unsafe {
                    if fma {
                        avx2::rows_f16_fma(a, w, k, n, out, skip)
                    } else {
                        avx2::rows_f16(a, w, k, n, out, skip)
                    }
                }
            } else {
                portable::rows_u16(a, w, k, n, out, skip, crate::params::f16_to_f32)
            }
        }
        PanelRef::Int8 { q, scales } => {
            if on_avx2 {
                // SAFETY: AVX2 (and FMA where taken) confirmed at runtime.
                unsafe {
                    if fma {
                        avx2::rows_i8_fma(a, q, scales, k, n, out, skip)
                    } else {
                        avx2::rows_i8(a, q, scales, k, n, out, skip)
                    }
                }
            } else {
                portable::rows_i8(a, q, scales, k, n, out, skip)
            }
        }
    }
}

#[cfg(not(target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
fn rows_dispatch_panel(
    _kernel: Kernel,
    a: &[f32],
    b: PanelRef<'_>,
    k: usize,
    n: usize,
    out: &mut [f32],
    skip: bool,
    _fast: bool,
) {
    match b {
        PanelRef::F32(w) => portable::matmul_rows(a, w, k, n, out, skip),
        PanelRef::Bf16(w) => portable::rows_u16(a, w, k, n, out, skip, crate::params::bf16_to_f32),
        PanelRef::F16(w) => portable::rows_u16(a, w, k, n, out, skip, crate::params::f16_to_f32),
        PanelRef::Int8 { q, scales } => portable::rows_i8(a, q, scales, k, n, out, skip),
    }
}

/// Row-block kernel dispatch (see module docs for the tier map).
fn rows_dispatch(
    kernel: Kernel,
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    out: &mut [f32],
    skip: bool,
) {
    match kernel {
        Kernel::Avx2 => rows_avx2(a, b, k, n, out, skip),
        Kernel::Portable => portable::matmul_rows(a, b, k, n, out, skip),
    }
}

#[cfg(target_arch = "x86_64")]
fn rows_avx2(a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32], skip: bool) {
    if simd::has_avx2() {
        // SAFETY: AVX2 support was just confirmed at runtime.
        unsafe { avx2::matmul_rows(a, b, k, n, out, skip) }
    } else {
        portable::matmul_rows(a, b, k, n, out, skip)
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn rows_avx2(a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32], skip: bool) {
    portable::matmul_rows(a, b, k, n, out, skip)
}

/// The seed scalar mat-vec, kept verbatim (per-row streaming passes with
/// the zero-input skip): equivalence oracle and bench baseline for the
/// vectorized arms. Single-threaded by design.
pub fn matmul_scalar(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for r in 0..m {
        let arow = &a[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        orow.fill(0.0);
        for (i, &x) in arow.iter().enumerate() {
            if x == 0.0 {
                continue; // the seed mat-vec's sparse-input skip
            }
            let brow = &b[i * n..(i + 1) * n];
            for (o, &w) in orow.iter_mut().zip(brow) {
                *o += x * w;
            }
        }
    }
}

/// [`matmul_scalar`] without the zero-input skip: the seed logits head's
/// accumulation order on a pre-transposed panel. Oracle for the `_dense`
/// vectorized arms.
pub fn matmul_dense_scalar(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for r in 0..m {
        let arow = &a[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        orow.fill(0.0);
        for (i, &x) in arow.iter().enumerate() {
            let brow = &b[i * n..(i + 1) * n];
            for (o, &w) in orow.iter_mut().zip(brow) {
                *o += x * w;
            }
        }
    }
}

/// `out[m,n] = a[m,k] × b[n,k]ᵀ` — the seed weight-tied logits head (`b` is
/// the token-embedding table, row-major `[vocab, d]`). Contiguous row-row
/// dot products; `k` accumulates in order. **No longer on the hot path**:
/// the runtime prepacks the embedding into `[D, V]` at model load and runs
/// the head through [`matmul_dense`], which accumulates in the identical
/// per-element order. Kept as the oracle and bench baseline for that claim.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for r in 0..m {
        let arow = &a[r * k..(r + 1) * k];
        for t in 0..n {
            let brow = &b[t * k..(t + 1) * k];
            let mut acc = 0.0f32;
            for (x, w) in arow.iter().zip(brow) {
                acc += x * w;
            }
            out[r * n + t] = acc;
        }
    }
}

/// Portable chunked-lane arm: the same code path on every architecture.
/// Column tiles of [`simd::LANES`] accumulators stay in registers across
/// the whole `k` loop (the seed kernel re-loaded and re-stored the output
/// tile on every `k` step), with `k` strictly in index order per element.
mod portable {
    use crate::runtime::simd::LANES;

    pub fn matmul_rows(a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32], skip: bool) {
        if n == 0 {
            return;
        }
        let rows = out.len() / n;
        for r in 0..rows {
            let arow = &a[r * k..(r + 1) * k];
            let orow = &mut out[r * n..(r + 1) * n];
            let mut jb = 0usize;
            while jb + LANES <= n {
                let mut acc = [0.0f32; LANES];
                for (i, &x) in arow.iter().enumerate() {
                    if skip && x == 0.0 {
                        continue;
                    }
                    let btile = &b[i * n + jb..i * n + jb + LANES];
                    for (l, acc_l) in acc.iter_mut().enumerate() {
                        *acc_l += x * btile[l];
                    }
                }
                orow[jb..jb + LANES].copy_from_slice(&acc);
                jb += LANES;
            }
            if jb < n {
                tail_cols(arow, b, n, jb, &mut orow[jb..], skip);
            }
        }
    }

    /// Scalar tail for the `n % LANES` trailing columns (same `i` order).
    pub fn tail_cols(arow: &[f32], b: &[f32], n: usize, jb: usize, out: &mut [f32], skip: bool) {
        out.fill(0.0);
        for (i, &x) in arow.iter().enumerate() {
            if skip && x == 0.0 {
                continue;
            }
            let btile = &b[i * n + jb..i * n + n];
            for (o, &w) in out.iter_mut().zip(btile) {
                *o += x * w;
            }
        }
    }

    /// Fused-dequant arm for 16-bit float panels (bf16/f16 — `cvt` is the
    /// exact widening, monomorphized per dtype). Same lane structure and
    /// per-element `i` order as [`matmul_rows`], so for a fixed panel this
    /// is bitwise-equal to the AVX2 dequant kernel and to [`matmul_rows`]
    /// over the dequantized panel.
    pub fn rows_u16(
        a: &[f32],
        w: &[u16],
        k: usize,
        n: usize,
        out: &mut [f32],
        skip: bool,
        cvt: impl Fn(u16) -> f32 + Copy,
    ) {
        if n == 0 {
            return;
        }
        let rows = out.len() / n;
        for r in 0..rows {
            let arow = &a[r * k..(r + 1) * k];
            let orow = &mut out[r * n..(r + 1) * n];
            let mut jb = 0usize;
            while jb + LANES <= n {
                let mut acc = [0.0f32; LANES];
                for (i, &x) in arow.iter().enumerate() {
                    if skip && x == 0.0 {
                        continue;
                    }
                    let wtile = &w[i * n + jb..i * n + jb + LANES];
                    for (l, acc_l) in acc.iter_mut().enumerate() {
                        *acc_l += x * cvt(wtile[l]);
                    }
                }
                orow[jb..jb + LANES].copy_from_slice(&acc);
                jb += LANES;
            }
            if jb < n {
                tail_u16(arow, w, n, jb, &mut orow[jb..], skip, cvt);
            }
        }
    }

    /// Scalar dequant tail for the `n % LANES` trailing columns.
    pub fn tail_u16(
        arow: &[f32],
        w: &[u16],
        n: usize,
        jb: usize,
        out: &mut [f32],
        skip: bool,
        cvt: impl Fn(u16) -> f32 + Copy,
    ) {
        out.fill(0.0);
        for (i, &x) in arow.iter().enumerate() {
            if skip && x == 0.0 {
                continue;
            }
            let wtile = &w[i * n + jb..i * n + n];
            for (o, &h) in out.iter_mut().zip(wtile) {
                *o += x * cvt(h);
            }
        }
    }

    /// Fused-dequant arm for int8 panels: the per-`k`-row scale is folded
    /// into the broadcast input once per `i` step (`xs = x · scale_i`), so
    /// the inner loop is one widen + mul + add per lane. The AVX2 kernel
    /// uses the identical fold, keeping the arms bitwise-equal.
    pub fn rows_i8(
        a: &[f32],
        q: &[i8],
        scales: &[f32],
        k: usize,
        n: usize,
        out: &mut [f32],
        skip: bool,
    ) {
        if n == 0 {
            return;
        }
        let rows = out.len() / n;
        for r in 0..rows {
            let arow = &a[r * k..(r + 1) * k];
            let orow = &mut out[r * n..(r + 1) * n];
            let mut jb = 0usize;
            while jb + LANES <= n {
                let mut acc = [0.0f32; LANES];
                for (i, &x) in arow.iter().enumerate() {
                    if skip && x == 0.0 {
                        continue;
                    }
                    let xs = x * scales[i];
                    let qtile = &q[i * n + jb..i * n + jb + LANES];
                    for (l, acc_l) in acc.iter_mut().enumerate() {
                        *acc_l += xs * qtile[l] as f32;
                    }
                }
                orow[jb..jb + LANES].copy_from_slice(&acc);
                jb += LANES;
            }
            if jb < n {
                tail_i8(arow, q, scales, n, jb, &mut orow[jb..], skip);
            }
        }
    }

    /// Scalar dequant tail for int8 trailing columns (same scale fold).
    pub fn tail_i8(
        arow: &[f32],
        q: &[i8],
        scales: &[f32],
        n: usize,
        jb: usize,
        out: &mut [f32],
        skip: bool,
    ) {
        out.fill(0.0);
        for (i, &x) in arow.iter().enumerate() {
            if skip && x == 0.0 {
                continue;
            }
            let xs = x * scales[i];
            let qtile = &q[i * n + jb..i * n + n];
            for (o, &qe) in out.iter_mut().zip(qtile) {
                *o += xs * qe as f32;
            }
        }
    }
}

/// AVX2 arm: register-tiled micro-kernel, 4 rows × 16 columns of
/// accumulators held in ymm registers across the whole `k` loop. Every
/// accumulate is `_mm256_add_ps(acc, _mm256_mul_ps(x, b))` — separate mul
/// and add, never `fmadd`, because fusing rounds once where the seed scalar
/// path rounds twice and would break bitwise equivalence.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn matmul_rows(
        a: &[f32],
        b: &[f32],
        k: usize,
        n: usize,
        out: &mut [f32],
        skip: bool,
    ) {
        if n == 0 {
            return;
        }
        let rows = out.len() / n;
        let mut r = 0usize;
        // SAFETY: AVX2 is present per the fn contract; each block call gets
        // matching row slices of `a` and `out` (bounds enforced by the slice
        // indexing itself), satisfying the block kernels' contracts.
        unsafe {
            while r + 4 <= rows {
                row_block4(&a[r * k..(r + 4) * k], b, k, n, &mut out[r * n..(r + 4) * n], skip);
                r += 4;
            }
            while r < rows {
                row_block1(&a[r * k..(r + 1) * k], b, k, n, &mut out[r * n..(r + 1) * n], skip);
                r += 1;
            }
        }
    }

    /// 4 rows × 16 columns per tile: 8 ymm accumulators, each weight tile
    /// loaded once and reused by all four rows.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime and pass
    /// `a.len() >= 4 * k`, `b.len() >= k * n`, `out.len() >= 4 * n`.
    #[target_feature(enable = "avx2")]
    unsafe fn row_block4(a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32], skip: bool) {
        // SAFETY: loads stay in bounds — `jb + 16 <= n` (resp. `jb + 8`)
        // gives `i*n + jb + 16 <= (i+1)*n <= k*n <= b.len()`, row indices
        // `rr * k + i < 4 * k <= a.len()`, stores `rr * n + jb + 16 <=
        // (rr+1)*n <= out.len()`; avx2 is present per the fn contract.
        unsafe {
            let mut jb = 0usize;
            while jb + 16 <= n {
                let mut acc = [_mm256_setzero_ps(); 8];
                for i in 0..k {
                    let b0 = _mm256_loadu_ps(b.as_ptr().add(i * n + jb));
                    let b1 = _mm256_loadu_ps(b.as_ptr().add(i * n + jb + 8));
                    for rr in 0..4 {
                        let x = *a.get_unchecked(rr * k + i);
                        if skip && x == 0.0 {
                            continue; // per-(row, i) skip, same as the seed path
                        }
                        let xv = _mm256_set1_ps(x);
                        acc[rr * 2] = _mm256_add_ps(acc[rr * 2], _mm256_mul_ps(xv, b0));
                        acc[rr * 2 + 1] = _mm256_add_ps(acc[rr * 2 + 1], _mm256_mul_ps(xv, b1));
                    }
                }
                for rr in 0..4 {
                    _mm256_storeu_ps(out.as_mut_ptr().add(rr * n + jb), acc[rr * 2]);
                    _mm256_storeu_ps(out.as_mut_ptr().add(rr * n + jb + 8), acc[rr * 2 + 1]);
                }
                jb += 16;
            }
            while jb + 8 <= n {
                let mut acc = [_mm256_setzero_ps(); 4];
                for i in 0..k {
                    let b0 = _mm256_loadu_ps(b.as_ptr().add(i * n + jb));
                    for (rr, acc_r) in acc.iter_mut().enumerate() {
                        let x = *a.get_unchecked(rr * k + i);
                        if skip && x == 0.0 {
                            continue;
                        }
                        *acc_r = _mm256_add_ps(*acc_r, _mm256_mul_ps(_mm256_set1_ps(x), b0));
                    }
                }
                for (rr, acc_r) in acc.iter().enumerate() {
                    _mm256_storeu_ps(out.as_mut_ptr().add(rr * n + jb), *acc_r);
                }
                jb += 8;
            }
            if jb < n {
                for rr in 0..4 {
                    super::portable::tail_cols(
                        &a[rr * k..(rr + 1) * k],
                        b,
                        n,
                        jb,
                        &mut out[rr * n + jb..rr * n + n],
                        skip,
                    );
                }
            }
        }
    }

    /// Single-row kernel for the `rows % 4` remainder.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime and pass
    /// `arow.len() >= k`, `b.len() >= k * n`, `out.len() >= n`.
    #[target_feature(enable = "avx2")]
    unsafe fn row_block1(
        arow: &[f32],
        b: &[f32],
        k: usize,
        n: usize,
        out: &mut [f32],
        skip: bool,
    ) {
        // SAFETY: `jb + 16 <= n` (resp. `jb + 8`) keeps weight loads inside
        // `b[..k*n]` and stores inside `out[..n]`; `i < k <= arow.len()`
        // bounds the row reads; avx2 is present per the fn contract.
        unsafe {
            let mut jb = 0usize;
            while jb + 16 <= n {
                let mut acc0 = _mm256_setzero_ps();
                let mut acc1 = _mm256_setzero_ps();
                for i in 0..k {
                    let x = *arow.get_unchecked(i);
                    if skip && x == 0.0 {
                        continue;
                    }
                    let xv = _mm256_set1_ps(x);
                    let b0 = _mm256_loadu_ps(b.as_ptr().add(i * n + jb));
                    let b1 = _mm256_loadu_ps(b.as_ptr().add(i * n + jb + 8));
                    acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(xv, b0));
                    acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(xv, b1));
                }
                _mm256_storeu_ps(out.as_mut_ptr().add(jb), acc0);
                _mm256_storeu_ps(out.as_mut_ptr().add(jb + 8), acc1);
                jb += 16;
            }
            while jb + 8 <= n {
                let mut acc = _mm256_setzero_ps();
                for i in 0..k {
                    let x = *arow.get_unchecked(i);
                    if skip && x == 0.0 {
                        continue;
                    }
                    let xv = _mm256_set1_ps(x);
                    let b0 = _mm256_loadu_ps(b.as_ptr().add(i * n + jb));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, b0));
                }
                _mm256_storeu_ps(out.as_mut_ptr().add(jb), acc);
                jb += 8;
            }
            if jb < n {
                super::portable::tail_cols(arow, b, n, jb, &mut out[jb..], skip);
            }
        }
    }

    // --- fused dequant-in-register kernels (narrow weight panels) and the
    // --- fast-tier FMA micro-kernel. Single-row blocked: decode-round `m`
    // --- is small and the weight stream, not register reuse, is the
    // --- bottleneck these tiers exist to shrink.

    /// Widen 8 bf16 values to f32 lanes: zero-extend u16→u32, shift left
    /// 16 into the f32 bit layout. Bit-exact dequantization.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime; `p` must be valid
    /// for reading 8 `u16` values (16 bytes, unaligned ok).
    #[target_feature(enable = "avx2")]
    unsafe fn load_bf16(p: *const u16) -> __m256 {
        // SAFETY: unaligned 16-byte read from `p`, valid per the fn contract.
        unsafe {
            let h = _mm_loadu_si128(p as *const __m128i);
            _mm256_castsi256_ps(_mm256_slli_epi32(_mm256_cvtepu16_epi32(h), 16))
        }
    }

    /// Widen 8 IEEE half values to f32 lanes (F16C; exact).
    ///
    /// # Safety
    /// Caller must have verified AVX2 + F16C support at runtime; `p` must be
    /// valid for reading 8 `u16` values (16 bytes, unaligned ok).
    #[target_feature(enable = "avx2,f16c")]
    unsafe fn load_f16(p: *const u16) -> __m256 {
        // SAFETY: unaligned 16-byte read from `p`, valid per the fn contract.
        unsafe { _mm256_cvtph_ps(_mm_loadu_si128(p as *const __m128i)) }
    }

    /// Widen 8 int8 values to f32 lanes (exact — i8 fits f32's mantissa).
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime; `p` must be valid
    /// for reading 8 `i8` values (8 bytes, unaligned ok).
    #[target_feature(enable = "avx2")]
    unsafe fn load_i8(p: *const i8) -> __m256 {
        // SAFETY: unaligned 8-byte read from `p`, valid per the fn contract.
        unsafe { _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_loadl_epi64(p as *const __m128i))) }
    }

    /// Generates one u16-panel (bf16/f16) row kernel per (feature set,
    /// accumulate op). The `$fma` arm folds each multiply-accumulate into
    /// `_mm256_fmadd_ps` (fast tier); the exact arm keeps separate
    /// mul + add so it stays bitwise-equal to the portable dequant loop.
    /// Scalar column tails reuse the portable tail with the scalar `$cvt`,
    /// which performs the identical exact widening.
    macro_rules! rows_u16_kernel {
        ($fname:ident, $feat:literal, $fma:expr, $load:ident, $cvt:path) => {
            /// # Safety
            /// Caller must have verified the listed features at runtime.
            #[target_feature(enable = $feat)]
            pub unsafe fn $fname(
                a: &[f32],
                w: &[u16],
                k: usize,
                n: usize,
                out: &mut [f32],
                skip: bool,
            ) {
                const FMA: bool = $fma;
                if n == 0 {
                    return;
                }
                let rows = out.len() / n;
                // SAFETY: `jb + 16 <= n` (resp. `jb + 8`) keeps panel loads
                // inside `w[..k*n]` and stores inside the `orow` slice;
                // `i < k` bounds the `arow` reads; the listed target
                // features are present per the fn contract.
                unsafe {
                    for r in 0..rows {
                        let arow = &a[r * k..(r + 1) * k];
                        let orow = &mut out[r * n..(r + 1) * n];
                        let mut jb = 0usize;
                        while jb + 16 <= n {
                            let mut acc0 = _mm256_setzero_ps();
                            let mut acc1 = _mm256_setzero_ps();
                            for i in 0..k {
                                let x = *arow.get_unchecked(i);
                                if skip && x == 0.0 {
                                    continue;
                                }
                                let xv = _mm256_set1_ps(x);
                                let w0 = $load(w.as_ptr().add(i * n + jb));
                                let w1 = $load(w.as_ptr().add(i * n + jb + 8));
                                if FMA {
                                    acc0 = _mm256_fmadd_ps(xv, w0, acc0);
                                    acc1 = _mm256_fmadd_ps(xv, w1, acc1);
                                } else {
                                    acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(xv, w0));
                                    acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(xv, w1));
                                }
                            }
                            _mm256_storeu_ps(orow.as_mut_ptr().add(jb), acc0);
                            _mm256_storeu_ps(orow.as_mut_ptr().add(jb + 8), acc1);
                            jb += 16;
                        }
                        while jb + 8 <= n {
                            let mut acc = _mm256_setzero_ps();
                            for i in 0..k {
                                let x = *arow.get_unchecked(i);
                                if skip && x == 0.0 {
                                    continue;
                                }
                                let xv = _mm256_set1_ps(x);
                                let w0 = $load(w.as_ptr().add(i * n + jb));
                                if FMA {
                                    acc = _mm256_fmadd_ps(xv, w0, acc);
                                } else {
                                    acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, w0));
                                }
                            }
                            _mm256_storeu_ps(orow.as_mut_ptr().add(jb), acc);
                            jb += 8;
                        }
                        if jb < n {
                            super::portable::tail_u16(arow, w, n, jb, &mut orow[jb..], skip, $cvt);
                        }
                    }
                }
            }
        };
    }

    rows_u16_kernel!(rows_bf16, "avx2", false, load_bf16, crate::params::bf16_to_f32);
    rows_u16_kernel!(rows_bf16_fma, "avx2,fma", true, load_bf16, crate::params::bf16_to_f32);
    rows_u16_kernel!(rows_f16, "avx2,f16c", false, load_f16, crate::params::f16_to_f32);
    rows_u16_kernel!(rows_f16_fma, "avx2,f16c,fma", true, load_f16, crate::params::f16_to_f32);

    /// Generates the int8 row kernels: per-`k`-row scale folded into the
    /// broadcast input (`xs = x · scale_i`, one scalar mul per `i` step),
    /// then widen-convert + multiply-accumulate per lane — the identical
    /// fold order as `portable::rows_i8`, keeping the arms bitwise-equal
    /// on the exact tier.
    macro_rules! rows_i8_kernel {
        ($fname:ident, $feat:literal, $fma:expr) => {
            /// # Safety
            /// Caller must have verified the listed features at runtime.
            #[target_feature(enable = $feat)]
            pub unsafe fn $fname(
                a: &[f32],
                q: &[i8],
                scales: &[f32],
                k: usize,
                n: usize,
                out: &mut [f32],
                skip: bool,
            ) {
                const FMA: bool = $fma;
                if n == 0 {
                    return;
                }
                let rows = out.len() / n;
                // SAFETY: `jb + 16 <= n` (resp. `jb + 8`) keeps panel loads
                // inside `q[..k*n]` and stores inside the `orow` slice;
                // `i < k` bounds the `arow` and `scales` reads; the listed
                // target features are present per the fn contract.
                unsafe {
                    for r in 0..rows {
                        let arow = &a[r * k..(r + 1) * k];
                        let orow = &mut out[r * n..(r + 1) * n];
                        let mut jb = 0usize;
                        while jb + 16 <= n {
                            let mut acc0 = _mm256_setzero_ps();
                            let mut acc1 = _mm256_setzero_ps();
                            for i in 0..k {
                                let x = *arow.get_unchecked(i);
                                if skip && x == 0.0 {
                                    continue;
                                }
                                let xv = _mm256_set1_ps(x * *scales.get_unchecked(i));
                                let q0 = load_i8(q.as_ptr().add(i * n + jb));
                                let q1 = load_i8(q.as_ptr().add(i * n + jb + 8));
                                if FMA {
                                    acc0 = _mm256_fmadd_ps(xv, q0, acc0);
                                    acc1 = _mm256_fmadd_ps(xv, q1, acc1);
                                } else {
                                    acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(xv, q0));
                                    acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(xv, q1));
                                }
                            }
                            _mm256_storeu_ps(orow.as_mut_ptr().add(jb), acc0);
                            _mm256_storeu_ps(orow.as_mut_ptr().add(jb + 8), acc1);
                            jb += 16;
                        }
                        while jb + 8 <= n {
                            let mut acc = _mm256_setzero_ps();
                            for i in 0..k {
                                let x = *arow.get_unchecked(i);
                                if skip && x == 0.0 {
                                    continue;
                                }
                                let xv = _mm256_set1_ps(x * *scales.get_unchecked(i));
                                let q0 = load_i8(q.as_ptr().add(i * n + jb));
                                if FMA {
                                    acc = _mm256_fmadd_ps(xv, q0, acc);
                                } else {
                                    acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, q0));
                                }
                            }
                            _mm256_storeu_ps(orow.as_mut_ptr().add(jb), acc);
                            jb += 8;
                        }
                        if jb < n {
                            super::portable::tail_i8(arow, q, scales, n, jb, &mut orow[jb..], skip);
                        }
                    }
                }
            }
        };
    }

    rows_i8_kernel!(rows_i8, "avx2", false);
    rows_i8_kernel!(rows_i8_fma, "avx2,fma", true);

    /// FMA variant of the f32 micro-kernel (fast tier only): one rounding
    /// per multiply-accumulate instead of two — deliberately off the
    /// bitwise contract, bounded by `tests/fast_tier.rs`.
    ///
    /// # Safety
    /// Caller must have verified AVX2 + FMA support at runtime.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn rows_f32_fma(
        a: &[f32],
        b: &[f32],
        k: usize,
        n: usize,
        out: &mut [f32],
        skip: bool,
    ) {
        if n == 0 {
            return;
        }
        let rows = out.len() / n;
        // SAFETY: `jb + 16 <= n` (resp. `jb + 8`) keeps weight loads inside
        // `b[..k*n]` and stores inside the `orow` slice; `i < k` bounds the
        // `arow` reads; AVX2 + FMA are present per the fn contract.
        unsafe {
            for r in 0..rows {
                let arow = &a[r * k..(r + 1) * k];
                let orow = &mut out[r * n..(r + 1) * n];
                let mut jb = 0usize;
                while jb + 16 <= n {
                    let mut acc0 = _mm256_setzero_ps();
                    let mut acc1 = _mm256_setzero_ps();
                    for i in 0..k {
                        let x = *arow.get_unchecked(i);
                        if skip && x == 0.0 {
                            continue;
                        }
                        let xv = _mm256_set1_ps(x);
                        acc0 =
                            _mm256_fmadd_ps(xv, _mm256_loadu_ps(b.as_ptr().add(i * n + jb)), acc0);
                        acc1 = _mm256_fmadd_ps(
                            xv,
                            _mm256_loadu_ps(b.as_ptr().add(i * n + jb + 8)),
                            acc1,
                        );
                    }
                    _mm256_storeu_ps(orow.as_mut_ptr().add(jb), acc0);
                    _mm256_storeu_ps(orow.as_mut_ptr().add(jb + 8), acc1);
                    jb += 16;
                }
                while jb + 8 <= n {
                    let mut acc = _mm256_setzero_ps();
                    for i in 0..k {
                        let x = *arow.get_unchecked(i);
                        if skip && x == 0.0 {
                            continue;
                        }
                        let xv = _mm256_set1_ps(x);
                        acc = _mm256_fmadd_ps(xv, _mm256_loadu_ps(b.as_ptr().add(i * n + jb)), acc);
                    }
                    _mm256_storeu_ps(orow.as_mut_ptr().add(jb), acc);
                    jb += 8;
                }
                if jb < n {
                    super::portable::tail_cols(arow, b, n, jb, &mut orow[jb..], skip);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg64;

    fn randv(n: usize, rng: &mut Pcg64) -> Vec<f32> {
        (0..n).map(|_| (rng.gaussian() * 0.5) as f32).collect()
    }

    /// Same per-element accumulation order as the kernels: i in order.
    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for r in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for i in 0..k {
                    acc += a[r * k + i] * b[i * n + j];
                }
                out[r * n + j] = acc;
            }
        }
        out
    }

    fn bits_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn matches_naive_bitwise_across_shapes() {
        let mut rng = Pcg64::new(11);
        for &(m, k, n) in &[(1, 16, 16), (3, 7, 300), (5, 64, 64), (8, 33, 257), (2, 1, 1)] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let mut out = vec![0.0f32; m * n];
            matmul(&a, &b, m, k, n, &mut out);
            let want = naive(&a, &b, m, k, n);
            assert!(bits_eq(&out, &want), "({m},{k},{n}) not bitwise equal");
        }
    }

    #[test]
    fn parallel_path_matches_naive() {
        // 2*m*k*n >= PAR_FLOPS so the row-partitioned path engages.
        let (m, k, n) = (64, 64, 600);
        let mut rng = Pcg64::new(3);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let mut out = vec![0.0f32; m * n];
        matmul(&a, &b, m, k, n, &mut out);
        let want = naive(&a, &b, m, k, n);
        assert!(bits_eq(&out, &want));
    }

    #[test]
    fn nt_matches_transposed_naive() {
        let (m, k, n) = (4, 24, 32);
        let mut rng = Pcg64::new(7);
        let a = randv(m * k, &mut rng);
        let bt = randv(n * k, &mut rng); // [n, k]
        let mut b = vec![0.0f32; k * n]; // [k, n]
        for t in 0..n {
            for i in 0..k {
                b[i * n + t] = bt[t * k + i];
            }
        }
        let mut out = vec![0.0f32; m * n];
        matmul_nt(&a, &bt, m, k, n, &mut out);
        let want = naive(&a, &b, m, k, n);
        for (x, y) in out.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn zero_rows_and_inputs_are_safe() {
        let b = [1.0f32, 2.0, 3.0, 4.0];
        let mut empty: [f32; 0] = [];
        matmul(&[], &b, 0, 2, 2, &mut empty);
        let a = [0.0f32, 1.0, 0.0, 2.0];
        let mut o = vec![0.0f32; 4];
        // [2,2] x [2,2]: zero inputs exercise the skip branch
        matmul(&a, &b, 2, 2, 2, &mut o);
        assert_eq!(o, vec![3.0, 4.0, 6.0, 8.0]);
    }

    /// The tentpole invariant at kernel level: the AVX2 arm, the portable
    /// arm, and the seed scalar kernel are bitwise-identical across
    /// randomized shapes — including non-multiple-of-lane widths, the
    /// 4-row block boundary, and exact-zero inputs (the skip edge).
    #[test]
    fn dispatch_arms_bitwise_equal_proptest() {
        check("matmul arms bitwise equal", 80, |g| {
            let m = g.usize_in(1..10);
            let k = g.usize_in(1..40);
            let n = g.usize_in(1..70);
            // ~30% exact zeros exercise the skip edge on every arm
            let a: Vec<f32> = (0..m * k)
                .map(|_| {
                    if g.f64_in(0.0..1.0) < 0.3 {
                        0.0
                    } else {
                        g.f64_in(-2.0..2.0) as f32
                    }
                })
                .collect();
            let b: Vec<f32> = (0..k * n).map(|_| g.f64_in(-2.0..2.0) as f32).collect();

            let mut scalar = vec![0.0f32; m * n];
            matmul_scalar(&a, &b, m, k, n, &mut scalar);
            for kernel in [Kernel::Avx2, Kernel::Portable] {
                let mut got = vec![0.0f32; m * n];
                matmul_st_with(kernel, &a, &b, m, k, n, &mut got);
                assert!(bits_eq(&got, &scalar), "{kernel:?} skip ({m},{k},{n})");
            }

            let mut scalar_d = vec![0.0f32; m * n];
            matmul_dense_scalar(&a, &b, m, k, n, &mut scalar_d);
            for kernel in [Kernel::Avx2, Kernel::Portable] {
                let mut got = vec![0.0f32; m * n];
                matmul_dense_st_with(kernel, &a, &b, m, k, n, &mut got);
                assert!(bits_eq(&got, &scalar_d), "{kernel:?} dense ({m},{k},{n})");
            }
        });
    }

    /// Row partitioning across the persistent pool must not change bits
    /// (chunks are whole rows; each element keeps its serial accumulator).
    #[test]
    fn parallel_rows_bitwise_equal_single_thread() {
        // 2*16*256*520 > PAR_FLOPS: the pool path engages (given >1 thread)
        let (m, k, n) = (16, 256, 520);
        let mut rng = Pcg64::new(29);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let mut par = vec![0.0f32; m * n];
        matmul(&a, &b, m, k, n, &mut par);
        let mut st = vec![0.0f32; m * n];
        matmul_st(&a, &b, m, k, n, &mut st);
        assert!(bits_eq(&par, &st), "row partitioning changed bits");
        let mut par_d = vec![0.0f32; m * n];
        matmul_dense(&a, &b, m, k, n, &mut par_d);
        let mut st_d = vec![0.0f32; m * n];
        matmul_dense_st(&a, &b, m, k, n, &mut st_d);
        assert!(bits_eq(&par_d, &st_d), "dense row partitioning changed bits");
    }
}
