//! Blocked GEMM micro-kernels for the pure-Rust runtime.
//!
//! The batched draft/verify paths funnel every projection (`[B,D]×[D,N]`,
//! weights row-major `[in, out]`) and the weight-tied logits head
//! (`[B,D]×[V,D]ᵀ`) through these two kernels, so all `c` candidate rows —
//! or all `G` teacher-forced feed positions — share one streaming pass over
//! each weight matrix instead of `B` scalar mat-vecs.
//!
//! Two properties the rest of the runtime relies on:
//!
//!   * **Bitwise-stable accumulation.** Each output element accumulates
//!     over the shared `k` dimension strictly in index order with a single
//!     accumulator, exactly like the seed scalar mat-vec (including its
//!     skip of zero inputs). Column tiling and row partitioning only
//!     reorder *independent* accumulators, so results are bit-identical to
//!     the per-position reference path — `tests/cpu_batched_equivalence.rs`
//!     asserts this.
//!   * **Bounded threading.** Row-parallelism (via
//!     [`crate::util::threadpool::parallel_chunks_mut`]) only kicks in past
//!     a FLOP threshold, so tiny test models never pay thread overhead.

use crate::util::threadpool::parallel_chunks_mut;

/// Column-tile width in f32 lanes (1 KiB per accumulator row): the `B`
/// panel of one tile stays cache-resident while every row reuses it.
const COL_BLOCK: usize = 256;

/// 2·m·k·n below this runs single-threaded (thread spawn ≫ work).
const PAR_FLOPS: usize = 1 << 22;

/// `out[m,n] = a[m,k] × b[k,n]`, `b` row-major `[k,n]` (projection weights).
/// Overwrites `out`. Rows are partitioned across threads for large shapes.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let threads = if 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n) < PAR_FLOPS {
        1
    } else {
        std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1).min(m)
    };
    if threads <= 1 {
        matmul_rows(a, b, k, n, out);
        return;
    }
    let rows_per = (m + threads - 1) / threads;
    parallel_chunks_mut(out, rows_per * n, |ci, chunk| {
        let r0 = ci * rows_per;
        let rows = chunk.len() / n;
        matmul_rows(&a[r0 * k..(r0 + rows) * k], b, k, n, chunk);
    });
}

/// Serial row-block kernel, column-tiled so the weight panel streams
/// through cache once while every row of `a` reuses it.
fn matmul_rows(a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    let rows = out.len() / n;
    let mut jb = 0;
    while jb < n {
        let je = (jb + COL_BLOCK).min(n);
        for r in 0..rows {
            out[r * n + jb..r * n + je].fill(0.0);
        }
        for i in 0..k {
            let brow = &b[i * n + jb..i * n + je];
            for r in 0..rows {
                let x = a[r * k + i];
                if x == 0.0 {
                    continue; // mirror the scalar mat-vec's sparse-input skip
                }
                let orow = &mut out[r * n + jb..r * n + je];
                for (o, &w) in orow.iter_mut().zip(brow) {
                    *o += x * w;
                }
            }
        }
        jb = je;
    }
}

/// `out[m,n] = a[m,k] × b[n,k]ᵀ` — the weight-tied logits head (`b` is the
/// token-embedding table, row-major `[vocab, d]`). Contiguous row-row dot
/// products; `k` accumulates in order (bit-equal to the scalar head).
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for r in 0..m {
        let arow = &a[r * k..(r + 1) * k];
        for t in 0..n {
            let brow = &b[t * k..(t + 1) * k];
            let mut acc = 0.0f32;
            for (x, w) in arow.iter().zip(brow) {
                acc += x * w;
            }
            out[r * n + t] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn randv(n: usize, rng: &mut Pcg64) -> Vec<f32> {
        (0..n).map(|_| (rng.gaussian() * 0.5) as f32).collect()
    }

    /// Same per-element accumulation order as the kernels: i in order.
    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for r in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for i in 0..k {
                    acc += a[r * k + i] * b[i * n + j];
                }
                out[r * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn matches_naive_bitwise_across_shapes() {
        let mut rng = Pcg64::new(11);
        for &(m, k, n) in &[(1, 16, 16), (3, 7, 300), (5, 64, 64), (8, 33, 257), (2, 1, 1)] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let mut out = vec![0.0f32; m * n];
            matmul(&a, &b, m, k, n, &mut out);
            let want = naive(&a, &b, m, k, n);
            assert!(
                out.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()),
                "({m},{k},{n}) not bitwise equal"
            );
        }
    }

    #[test]
    fn parallel_path_matches_naive() {
        // 2*m*k*n >= PAR_FLOPS so the row-partitioned path engages.
        let (m, k, n) = (64, 64, 600);
        let mut rng = Pcg64::new(3);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let mut out = vec![0.0f32; m * n];
        matmul(&a, &b, m, k, n, &mut out);
        let want = naive(&a, &b, m, k, n);
        assert!(out.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn nt_matches_transposed_naive() {
        let (m, k, n) = (4, 24, 32);
        let mut rng = Pcg64::new(7);
        let a = randv(m * k, &mut rng);
        let bt = randv(n * k, &mut rng); // [n, k]
        let mut b = vec![0.0f32; k * n]; // [k, n]
        for t in 0..n {
            for i in 0..k {
                b[i * n + t] = bt[t * k + i];
            }
        }
        let mut out = vec![0.0f32; m * n];
        matmul_nt(&a, &bt, m, k, n, &mut out);
        let want = naive(&a, &b, m, k, n);
        for (x, y) in out.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn zero_rows_and_inputs_are_safe() {
        let b = [1.0f32, 2.0, 3.0, 4.0];
        let mut empty: [f32; 0] = [];
        matmul(&[], &b, 0, 2, 2, &mut empty);
        let a = [0.0f32, 1.0, 0.0, 2.0];
        let mut o = vec![0.0f32; 4];
        // [2,2] x [2,2]: zero inputs exercise the skip branch
        matmul(&a, &b, 2, 2, 2, &mut o);
        assert_eq!(o, vec![3.0, 4.0, 6.0, 8.0]);
    }
}
