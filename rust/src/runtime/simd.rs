//! SIMD dispatch tiers and elementwise lane helpers for the CPU runtime.
//!
//! # Dispatch tiers
//!
//! Every vectorized kernel in the runtime ([`super::gemm`] and the helpers
//! below) has two arms, selected **once per process** by [`active`]:
//!
//!   * [`Kernel::Avx2`] — explicit AVX2 via `std::arch`, taken when
//!     `is_x86_feature_detected!("avx2")` reports support;
//!   * [`Kernel::Portable`] — plain chunked-lane Rust, the same code path
//!     on every architecture (and the only one off x86-64). The
//!     `SPECMER_FORCE_PORTABLE` env var pins this arm on any machine so CI
//!     can keep both arms green.
//!
//! # The bitwise-stability argument
//!
//! The runtime's equivalence suites pin batched results to the seed scalar
//! implementation bit for bit, so vectorization may only reorder work
//! across **independent output elements**, never within one element's
//! accumulation:
//!
//!   * lanes run across independent outputs (GEMM output columns,
//!     elementwise slots), each lane performing the exact per-element
//!     operation chain of the scalar code;
//!   * every multiply-accumulate is a **separate mul then add** — never a
//!     fused multiply-add, which rounds once instead of twice and would
//!     change bits vs the seed path;
//!   * reductions with a single serial accumulator (LN mean/variance,
//!     attention QK dots, softmax normalizers) stay scalar in strict index
//!     order — splitting them across lanes would reassociate the sum;
//!   * transcendentals (GELU's `tanh`, softmax's `exp`) stay scalar libm
//!     calls — a vector polynomial approximation would change bits.
//!
//! IEEE-754 single ops (`mul`, `add`, `sub`) are exactly rounded and
//! lane-wise identical to their scalar counterparts, so both arms produce
//! bit-identical results — pinned by proptests in this module, in
//! [`super::gemm`], and in `tests/kernel_equivalence.rs`.

use std::sync::OnceLock;

/// f32 lanes per vector step (one AVX2 register).
pub const LANES: usize = 8;

/// Which kernel arm the runtime dispatches to (see module docs).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kernel {
    Avx2,
    Portable,
}

impl Kernel {
    /// Stable name for logs / bench JSON.
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Avx2 => "avx2",
            Kernel::Portable => "portable",
        }
    }
}

/// Whether this machine can execute the AVX2 arm.
#[cfg(target_arch = "x86_64")]
pub fn has_avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// Whether this machine can execute the AVX2 arm.
#[cfg(not(target_arch = "x86_64"))]
pub fn has_avx2() -> bool {
    false
}

/// The process-wide kernel arm, resolved once: `SPECMER_FORCE_PORTABLE`
/// (non-empty, not "0") pins the portable arm; otherwise AVX2 when
/// detected, portable everywhere else.
pub fn active() -> Kernel {
    static ACTIVE: OnceLock<Kernel> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let forced = std::env::var("SPECMER_FORCE_PORTABLE")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        if !forced && has_avx2() {
            Kernel::Avx2
        } else {
            Kernel::Portable
        }
    })
}

/// Clamp a requested arm to what this machine can execute (callers may ask
/// for [`Kernel::Avx2`] unconditionally, e.g. tests comparing both arms).
fn executable(kernel: Kernel) -> Kernel {
    match kernel {
        Kernel::Avx2 if !has_avx2() => Kernel::Portable,
        k => k,
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// out[j] += s[j]
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign(out: &mut [f32], s: &[f32]) {
        let n = out.len();
        let mut j = 0;
        while j + 8 <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(j));
            let x = _mm256_loadu_ps(s.as_ptr().add(j));
            _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_add_ps(o, x));
            j += 8;
        }
        while j < n {
            out[j] += s[j];
            j += 1;
        }
    }

    /// x[j] += p[j] + b[j]  (inner add first, exactly like the scalar code)
    #[target_feature(enable = "avx2")]
    pub unsafe fn add2_assign(x: &mut [f32], p: &[f32], b: &[f32]) {
        let n = x.len();
        let mut j = 0;
        while j + 8 <= n {
            let xv = _mm256_loadu_ps(x.as_ptr().add(j));
            let pv = _mm256_loadu_ps(p.as_ptr().add(j));
            let bv = _mm256_loadu_ps(b.as_ptr().add(j));
            _mm256_storeu_ps(
                x.as_mut_ptr().add(j),
                _mm256_add_ps(xv, _mm256_add_ps(pv, bv)),
            );
            j += 8;
        }
        while j < n {
            x[j] += p[j] + b[j];
            j += 1;
        }
    }

    /// x[j] = (x[j] - mu) * inv * g[j] + b[j]
    /// (mul, mul, add — no FMA, same chain as the scalar LN application)
    #[target_feature(enable = "avx2")]
    pub unsafe fn ln_apply(x: &mut [f32], g: &[f32], b: &[f32], mu: f32, inv: f32) {
        let n = x.len();
        let muv = _mm256_set1_ps(mu);
        let invv = _mm256_set1_ps(inv);
        let mut j = 0;
        while j + 8 <= n {
            let xv = _mm256_loadu_ps(x.as_ptr().add(j));
            let gv = _mm256_loadu_ps(g.as_ptr().add(j));
            let bv = _mm256_loadu_ps(b.as_ptr().add(j));
            let t = _mm256_mul_ps(_mm256_mul_ps(_mm256_sub_ps(xv, muv), invv), gv);
            _mm256_storeu_ps(x.as_mut_ptr().add(j), _mm256_add_ps(t, bv));
            j += 8;
        }
        while j < n {
            x[j] = (x[j] - mu) * inv * g[j] + b[j];
            j += 1;
        }
    }

    /// out[j] += w * v[j]  (attention weighted-V accumulation; mul then add)
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(w: f32, v: &[f32], out: &mut [f32]) {
        let n = out.len();
        let wv = _mm256_set1_ps(w);
        let mut j = 0;
        while j + 8 <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(j));
            let x = _mm256_loadu_ps(v.as_ptr().add(j));
            _mm256_storeu_ps(
                out.as_mut_ptr().add(j),
                _mm256_add_ps(o, _mm256_mul_ps(wv, x)),
            );
            j += 8;
        }
        while j < n {
            out[j] += w * v[j];
            j += 1;
        }
    }
}

mod portable {
    /// out[j] += s[j]
    pub fn add_assign(out: &mut [f32], s: &[f32]) {
        for (o, &x) in out.iter_mut().zip(s) {
            *o += x;
        }
    }

    /// x[j] += p[j] + b[j]
    pub fn add2_assign(x: &mut [f32], p: &[f32], b: &[f32]) {
        for ((xo, &pv), &bv) in x.iter_mut().zip(p).zip(b) {
            *xo += pv + bv;
        }
    }

    /// x[j] = (x[j] - mu) * inv * g[j] + b[j]
    pub fn ln_apply(x: &mut [f32], g: &[f32], b: &[f32], mu: f32, inv: f32) {
        for ((xo, &gv), &bv) in x.iter_mut().zip(g).zip(b) {
            *xo = (*xo - mu) * inv * gv + bv;
        }
    }

    /// out[j] += w * v[j]
    pub fn axpy(w: f32, v: &[f32], out: &mut [f32]) {
        for (o, &x) in out.iter_mut().zip(v) {
            *o += w * x;
        }
    }
}

/// Residual add: `out[j] += s[j]` elementwise.
pub fn add_assign(out: &mut [f32], s: &[f32]) {
    add_assign_with(active(), out, s)
}

/// [`add_assign`] on an explicit arm (tests compare both).
pub fn add_assign_with(kernel: Kernel, out: &mut [f32], s: &[f32]) {
    debug_assert_eq!(out.len(), s.len());
    match executable(kernel) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `executable` only yields Avx2 when the feature is present.
        Kernel::Avx2 => unsafe { avx2::add_assign(out, s) },
        _ => portable::add_assign(out, s),
    }
}

/// Residual + bias add: `x[j] += p[j] + b[j]` elementwise.
pub fn add2_assign(x: &mut [f32], p: &[f32], b: &[f32]) {
    add2_assign_with(active(), x, p, b)
}

/// [`add2_assign`] on an explicit arm (tests compare both).
pub fn add2_assign_with(kernel: Kernel, x: &mut [f32], p: &[f32], b: &[f32]) {
    debug_assert_eq!(x.len(), p.len());
    debug_assert_eq!(x.len(), b.len());
    match executable(kernel) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `executable` only yields Avx2 when the feature is present.
        Kernel::Avx2 => unsafe { avx2::add2_assign(x, p, b) },
        _ => portable::add2_assign(x, p, b),
    }
}

/// LayerNorm application: `x[j] = (x[j] - mu) * inv * g[j] + b[j]`. The
/// mean/variance reductions stay with the caller in scalar index order.
pub fn ln_apply(x: &mut [f32], g: &[f32], b: &[f32], mu: f32, inv: f32) {
    ln_apply_with(active(), x, g, b, mu, inv)
}

/// [`ln_apply`] on an explicit arm (tests compare both).
pub fn ln_apply_with(kernel: Kernel, x: &mut [f32], g: &[f32], b: &[f32], mu: f32, inv: f32) {
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(x.len(), b.len());
    match executable(kernel) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `executable` only yields Avx2 when the feature is present.
        Kernel::Avx2 => unsafe { avx2::ln_apply(x, g, b, mu, inv) },
        _ => portable::ln_apply(x, g, b, mu, inv),
    }
}

/// Weighted accumulate: `out[j] += w * v[j]` (the attention V inner loop).
pub fn axpy(w: f32, v: &[f32], out: &mut [f32]) {
    axpy_with(active(), w, v, out)
}

/// [`axpy`] on an explicit arm (tests compare both).
pub fn axpy_with(kernel: Kernel, w: f32, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), v.len());
    match executable(kernel) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `executable` only yields Avx2 when the feature is present.
        Kernel::Avx2 => unsafe { avx2::axpy(w, v, out) },
        _ => portable::axpy(w, v, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    fn randv(g: &mut Gen, n: usize) -> Vec<f32> {
        (0..n).map(|_| g.f64_in(-2.0..2.0) as f32).collect()
    }

    fn bits_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    /// Both arms of every elementwise helper agree bitwise with the scalar
    /// loop across lengths crossing the lane width (including 0 and tails).
    #[test]
    fn elementwise_helpers_bitwise_match_scalar() {
        check("simd elementwise == scalar", 120, |g| {
            let n = g.usize_in(0..37);
            let base = randv(g, n);
            let s = randv(g, n);
            let b = randv(g, n);
            let gg = randv(g, n);
            let mu = g.f64_in(-1.0..1.0) as f32;
            let inv = g.f64_in(0.1..2.0) as f32;
            let w = g.f64_in(-1.5..1.5) as f32;

            for kernel in [Kernel::Avx2, Kernel::Portable] {
                // add_assign
                let mut want = base.clone();
                for (o, &x) in want.iter_mut().zip(&s) {
                    *o += x;
                }
                let mut got = base.clone();
                add_assign_with(kernel, &mut got, &s);
                assert!(bits_eq(&got, &want), "{kernel:?} add_assign n={n}");

                // add2_assign
                let mut want = base.clone();
                for ((xo, &pv), &bv) in want.iter_mut().zip(&s).zip(&b) {
                    *xo += pv + bv;
                }
                let mut got = base.clone();
                add2_assign_with(kernel, &mut got, &s, &b);
                assert!(bits_eq(&got, &want), "{kernel:?} add2_assign n={n}");

                // ln_apply
                let mut want = base.clone();
                for ((xo, &gv), &bv) in want.iter_mut().zip(&gg).zip(&b) {
                    *xo = (*xo - mu) * inv * gv + bv;
                }
                let mut got = base.clone();
                ln_apply_with(kernel, &mut got, &gg, &b, mu, inv);
                assert!(bits_eq(&got, &want), "{kernel:?} ln_apply n={n}");

                // axpy
                let mut want = base.clone();
                for (o, &x) in want.iter_mut().zip(&s) {
                    *o += w * x;
                }
                let mut got = base.clone();
                axpy_with(kernel, w, &s, &mut got);
                assert!(bits_eq(&got, &want), "{kernel:?} axpy n={n}");
            }
        });
    }

    #[test]
    fn active_is_stable_and_portable_is_executable() {
        assert_eq!(active(), active());
        assert_eq!(executable(Kernel::Portable), Kernel::Portable);
        if !has_avx2() {
            assert_eq!(executable(Kernel::Avx2), Kernel::Portable);
        }
    }
}
