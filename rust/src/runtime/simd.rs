//! SIMD dispatch tiers and elementwise lane helpers for the CPU runtime.
//!
//! # Dispatch tiers
//!
//! Every vectorized kernel in the runtime ([`super::gemm`] and the helpers
//! below) has two arms, selected **once per process** by [`active`]:
//!
//!   * [`Kernel::Avx2`] — explicit AVX2 via `std::arch`, taken when
//!     `is_x86_feature_detected!("avx2")` reports support;
//!   * [`Kernel::Portable`] — plain chunked-lane Rust, the same code path
//!     on every architecture (and the only one off x86-64). The
//!     `SPECMER_FORCE_PORTABLE` env var pins this arm on any machine so CI
//!     can keep both arms green.
//!
//! # The bitwise-stability argument
//!
//! The runtime's equivalence suites pin batched results to the seed scalar
//! implementation bit for bit, so vectorization may only reorder work
//! across **independent output elements**, never within one element's
//! accumulation:
//!
//!   * lanes run across independent outputs (GEMM output columns,
//!     elementwise slots), each lane performing the exact per-element
//!     operation chain of the scalar code;
//!   * every multiply-accumulate is a **separate mul then add** — never a
//!     fused multiply-add, which rounds once instead of twice and would
//!     change bits vs the seed path;
//!   * reductions with a single serial accumulator (LN mean/variance,
//!     attention QK dots, softmax normalizers) stay scalar in strict index
//!     order — splitting them across lanes would reassociate the sum;
//!   * transcendentals (GELU's `tanh`, softmax's `exp`) stay scalar libm
//!     calls — a vector polynomial approximation would change bits.
//!
//! IEEE-754 single ops (`mul`, `add`, `sub`) are exactly rounded and
//! lane-wise identical to their scalar counterparts, so both arms produce
//! bit-identical results — pinned by proptests in this module, in
//! [`super::gemm`], and in `tests/kernel_equivalence.rs`.
//!
//! # Beyond the bitwise contract: weight dtype and the fast tier
//!
//! Two further process-wide dispatch axes resolve here and deliberately
//! step outside the bitwise pin:
//!
//!   * [`weight_dtype`] (`SPECMER_WEIGHT_DTYPE`) selects the storage dtype
//!     of the weight panels ([`crate::params::WeightDtype`]). Narrow
//!     dtypes round the weights once at load, so results differ from f32
//!     *by construction*; what stays pinned is cross-arm determinism — for
//!     a fixed dtype, the AVX2 and portable arms are bitwise-equal to each
//!     other and to a dequantize-then-f32 oracle (`tests/quantization.rs`).
//!   * [`fast_tier`] (`SPECMER_FAST`) enables FMA in the GEMM micro-kernel
//!     and the polynomial [`exp_fast`]/[`tanh_fast`] in softmax/GELU. FMA
//!     rounds once where the exact tier rounds twice and the polynomials
//!     replace libm, so this tier is validated by **accuracy bounds**
//!     (per-kernel max-ulp, end-to-end logit-delta / acceptance-rate
//!     tolerance in `tests/fast_tier.rs`), never bit-pins.
//!
//! Both default off: with `SPECMER_WEIGHT_DTYPE` unset and `SPECMER_FAST`
//! off, every path is the bitwise-exact tier described above.

use crate::params::WeightDtype;
use std::sync::OnceLock;

/// f32 lanes per vector step (one AVX2 register).
pub const LANES: usize = 8;

/// Which kernel arm the runtime dispatches to (see module docs).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kernel {
    Avx2,
    Portable,
}

impl Kernel {
    /// Stable name for logs / bench JSON.
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Avx2 => "avx2",
            Kernel::Portable => "portable",
        }
    }
}

/// Whether this machine can execute the AVX2 arm.
#[cfg(target_arch = "x86_64")]
pub fn has_avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// Whether this machine can execute the AVX2 arm.
#[cfg(not(target_arch = "x86_64"))]
pub fn has_avx2() -> bool {
    false
}

/// Whether the f16 half→single vector conversion (`_mm256_cvtph_ps`) is
/// available — F16C is a separate CPUID bit from AVX2.
#[cfg(target_arch = "x86_64")]
pub fn has_f16c() -> bool {
    std::arch::is_x86_feature_detected!("f16c")
}

#[cfg(not(target_arch = "x86_64"))]
pub fn has_f16c() -> bool {
    false
}

/// Whether the fused multiply-add arm of the fast tier can run.
#[cfg(target_arch = "x86_64")]
pub fn has_fma() -> bool {
    std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
pub fn has_fma() -> bool {
    false
}

/// Parse a boolean-ish env flag. `Some(true)` for "1"/"true"/"on"/"yes",
/// `Some(false)` for ""/"0"/"false"/"off"/"no" (case-insensitive), `None`
/// for anything else so the caller can warn instead of guessing.
pub(crate) fn parse_flag(raw: &str) -> Option<bool> {
    match raw.trim().to_ascii_lowercase().as_str() {
        "1" | "true" | "on" | "yes" => Some(true),
        "" | "0" | "false" | "off" | "no" => Some(false),
        _ => None,
    }
}

/// Resolve a flag env var once, warning (once, by construction — callers
/// cache in a `OnceLock`) when the value is unparsable and names the
/// fallback actually taken.
fn flag_env(var: &str, default: bool) -> bool {
    match std::env::var(var) {
        Ok(raw) => parse_flag(&raw).unwrap_or_else(|| {
            eprintln!(
                "[specmer] {var}={raw:?} is not a recognized flag value \
                 (1/true/on/yes or 0/false/off/no); falling back to {var}={}",
                if default { "1" } else { "0" }
            );
            default
        }),
        Err(_) => default,
    }
}

/// The process-wide kernel arm, resolved once: `SPECMER_FORCE_PORTABLE`
/// pins the portable arm (unparsable values warn once and fall back to the
/// default dispatch); otherwise AVX2 when detected, portable everywhere
/// else.
pub fn active() -> Kernel {
    static ACTIVE: OnceLock<Kernel> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        if !flag_env("SPECMER_FORCE_PORTABLE", false) && has_avx2() {
            Kernel::Avx2
        } else {
            Kernel::Portable
        }
    })
}

/// The process-wide weight-panel storage dtype, resolved once from
/// `SPECMER_WEIGHT_DTYPE` (`f32` | `bf16` | `f16` | `int8`). Unparsable
/// values warn once and fall back to the bitwise-exact f32 tier. Model
/// constructors take this as their default; tests/benches override per
/// model via the `*_with` constructors.
pub fn weight_dtype() -> WeightDtype {
    static DTYPE: OnceLock<WeightDtype> = OnceLock::new();
    *DTYPE.get_or_init(|| match std::env::var("SPECMER_WEIGHT_DTYPE") {
        Ok(raw) => WeightDtype::parse(&raw).unwrap_or_else(|| {
            eprintln!(
                "[specmer] SPECMER_WEIGHT_DTYPE={raw:?} is not a recognized dtype \
                 (f32|bf16|f16|int8); falling back to f32"
            );
            WeightDtype::F32
        }),
        Err(_) => WeightDtype::F32,
    })
}

/// Whether the accuracy-bounded fast tier (`SPECMER_FAST`) is on for this
/// process: FMA in the GEMM micro-kernel plus polynomial exp/tanh. Off by
/// default — the default tier keeps the bitwise-equivalence contract.
pub fn fast_tier() -> bool {
    static FAST: OnceLock<bool> = OnceLock::new();
    *FAST.get_or_init(|| flag_env("SPECMER_FAST", false))
}

/// Whether the opt-in runtime invariant validators (`SPECMER_VALIDATE`) are
/// on for this process. Debug builds call `debug_validate()` on the decode
/// data structures (`BranchedArena`, `TreeTails`, `LockstepGroup`) at round
/// boundaries when this is set; release builds compile the call sites out.
/// Off by default — validation walks every parent chain and KV row count.
pub fn validate_enabled() -> bool {
    static VALIDATE: OnceLock<bool> = OnceLock::new();
    *VALIDATE.get_or_init(|| flag_env("SPECMER_VALIDATE", false))
}

/// Clamp a requested arm to what this machine can execute (callers may ask
/// for [`Kernel::Avx2`] unconditionally, e.g. tests comparing both arms).
fn executable(kernel: Kernel) -> Kernel {
    match kernel {
        Kernel::Avx2 if !has_avx2() => Kernel::Portable,
        k => k,
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// out[j] += s[j]
    ///
    /// # Safety
    /// Caller must ensure the `avx2` target feature is present on this CPU
    /// (the dispatch sites check [`super::has_avx2`]) and that
    /// `s.len() >= out.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign(out: &mut [f32], s: &[f32]) {
        let n = out.len();
        let mut j = 0;
        while j + 8 <= n {
            // SAFETY: `j + 8 <= n` keeps every 8-lane load/store inside
            // `out[..n]` and `s[..n]`; avx2 is present per the fn contract.
            unsafe {
                let o = _mm256_loadu_ps(out.as_ptr().add(j));
                let x = _mm256_loadu_ps(s.as_ptr().add(j));
                _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_add_ps(o, x));
            }
            j += 8;
        }
        while j < n {
            out[j] += s[j];
            j += 1;
        }
    }

    /// x[j] += p[j] + b[j]  (inner add first, exactly like the scalar code)
    ///
    /// # Safety
    /// Caller must ensure the `avx2` target feature is present on this CPU
    /// and that `p.len() >= x.len()` and `b.len() >= x.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn add2_assign(x: &mut [f32], p: &[f32], b: &[f32]) {
        let n = x.len();
        let mut j = 0;
        while j + 8 <= n {
            // SAFETY: `j + 8 <= n` keeps every 8-lane load/store inside
            // `x[..n]`, `p[..n]`, `b[..n]`; avx2 per the fn contract.
            unsafe {
                let xv = _mm256_loadu_ps(x.as_ptr().add(j));
                let pv = _mm256_loadu_ps(p.as_ptr().add(j));
                let bv = _mm256_loadu_ps(b.as_ptr().add(j));
                _mm256_storeu_ps(
                    x.as_mut_ptr().add(j),
                    _mm256_add_ps(xv, _mm256_add_ps(pv, bv)),
                );
            }
            j += 8;
        }
        while j < n {
            x[j] += p[j] + b[j];
            j += 1;
        }
    }

    /// x[j] = (x[j] - mu) * inv * g[j] + b[j]
    /// (mul, mul, add — no FMA, same chain as the scalar LN application)
    ///
    /// # Safety
    /// Caller must ensure the `avx2` target feature is present on this CPU
    /// and that `g.len() >= x.len()` and `b.len() >= x.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn ln_apply(x: &mut [f32], g: &[f32], b: &[f32], mu: f32, inv: f32) {
        // SAFETY: `j + 8 <= n` keeps every 8-lane load/store inside `x[..n]`,
        // `g[..n]`, `b[..n]`; the scalar tail uses checked indexing; avx2 is
        // present per the fn contract.
        unsafe {
            let n = x.len();
            let muv = _mm256_set1_ps(mu);
            let invv = _mm256_set1_ps(inv);
            let mut j = 0;
            while j + 8 <= n {
                let xv = _mm256_loadu_ps(x.as_ptr().add(j));
                let gv = _mm256_loadu_ps(g.as_ptr().add(j));
                let bv = _mm256_loadu_ps(b.as_ptr().add(j));
                let t = _mm256_mul_ps(_mm256_mul_ps(_mm256_sub_ps(xv, muv), invv), gv);
                _mm256_storeu_ps(x.as_mut_ptr().add(j), _mm256_add_ps(t, bv));
                j += 8;
            }
            while j < n {
                x[j] = (x[j] - mu) * inv * g[j] + b[j];
                j += 1;
            }
        }
    }

    /// out[j] += w * v[j]  (attention weighted-V accumulation; mul then add)
    ///
    /// # Safety
    /// Caller must ensure the `avx2` target feature is present on this CPU
    /// and that `v.len() >= out.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(w: f32, v: &[f32], out: &mut [f32]) {
        // SAFETY: `j + 8 <= n` keeps every 8-lane load/store inside
        // `out[..n]` and `v[..n]`; the scalar tail uses checked indexing;
        // avx2 is present per the fn contract.
        unsafe {
            let n = out.len();
            let wv = _mm256_set1_ps(w);
            let mut j = 0;
            while j + 8 <= n {
                let o = _mm256_loadu_ps(out.as_ptr().add(j));
                let x = _mm256_loadu_ps(v.as_ptr().add(j));
                _mm256_storeu_ps(
                    out.as_mut_ptr().add(j),
                    _mm256_add_ps(o, _mm256_mul_ps(wv, x)),
                );
                j += 8;
            }
            while j < n {
                out[j] += w * v[j];
                j += 1;
            }
        }
    }
}

mod portable {
    /// out[j] += s[j]
    pub fn add_assign(out: &mut [f32], s: &[f32]) {
        for (o, &x) in out.iter_mut().zip(s) {
            *o += x;
        }
    }

    /// x[j] += p[j] + b[j]
    pub fn add2_assign(x: &mut [f32], p: &[f32], b: &[f32]) {
        for ((xo, &pv), &bv) in x.iter_mut().zip(p).zip(b) {
            *xo += pv + bv;
        }
    }

    /// x[j] = (x[j] - mu) * inv * g[j] + b[j]
    pub fn ln_apply(x: &mut [f32], g: &[f32], b: &[f32], mu: f32, inv: f32) {
        for ((xo, &gv), &bv) in x.iter_mut().zip(g).zip(b) {
            *xo = (*xo - mu) * inv * gv + bv;
        }
    }

    /// out[j] += w * v[j]
    pub fn axpy(w: f32, v: &[f32], out: &mut [f32]) {
        for (o, &x) in out.iter_mut().zip(v) {
            *o += w * x;
        }
    }
}

/// Residual add: `out[j] += s[j]` elementwise.
pub fn add_assign(out: &mut [f32], s: &[f32]) {
    add_assign_with(active(), out, s)
}

/// [`add_assign`] on an explicit arm (tests compare both).
pub fn add_assign_with(kernel: Kernel, out: &mut [f32], s: &[f32]) {
    debug_assert_eq!(out.len(), s.len());
    match executable(kernel) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `executable` only yields Avx2 when the feature is present.
        Kernel::Avx2 => unsafe { avx2::add_assign(out, s) },
        _ => portable::add_assign(out, s),
    }
}

/// Residual + bias add: `x[j] += p[j] + b[j]` elementwise.
pub fn add2_assign(x: &mut [f32], p: &[f32], b: &[f32]) {
    add2_assign_with(active(), x, p, b)
}

/// [`add2_assign`] on an explicit arm (tests compare both).
pub fn add2_assign_with(kernel: Kernel, x: &mut [f32], p: &[f32], b: &[f32]) {
    debug_assert_eq!(x.len(), p.len());
    debug_assert_eq!(x.len(), b.len());
    match executable(kernel) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `executable` only yields Avx2 when the feature is present.
        Kernel::Avx2 => unsafe { avx2::add2_assign(x, p, b) },
        _ => portable::add2_assign(x, p, b),
    }
}

/// LayerNorm application: `x[j] = (x[j] - mu) * inv * g[j] + b[j]`. The
/// mean/variance reductions stay with the caller in scalar index order.
pub fn ln_apply(x: &mut [f32], g: &[f32], b: &[f32], mu: f32, inv: f32) {
    ln_apply_with(active(), x, g, b, mu, inv)
}

/// [`ln_apply`] on an explicit arm (tests compare both).
pub fn ln_apply_with(kernel: Kernel, x: &mut [f32], g: &[f32], b: &[f32], mu: f32, inv: f32) {
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(x.len(), b.len());
    match executable(kernel) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `executable` only yields Avx2 when the feature is present.
        Kernel::Avx2 => unsafe { avx2::ln_apply(x, g, b, mu, inv) },
        _ => portable::ln_apply(x, g, b, mu, inv),
    }
}

/// Weighted accumulate: `out[j] += w * v[j]` (the attention V inner loop).
pub fn axpy(w: f32, v: &[f32], out: &mut [f32]) {
    axpy_with(active(), w, v, out)
}

/// [`axpy`] on an explicit arm (tests compare both).
pub fn axpy_with(kernel: Kernel, w: f32, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), v.len());
    match executable(kernel) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `executable` only yields Avx2 when the feature is present.
        Kernel::Avx2 => unsafe { avx2::axpy(w, v, out) },
        _ => portable::axpy(w, v, out),
    }
}

// ---------------------------------------------------------------------------
// Fast-tier transcendentals (accuracy-bounded; never on the default tier).
//
// Branch-light scalar polynomials: no lookup tables, no data-dependent
// branches in the hot range, so LLVM can unroll/auto-vectorize them inside
// the GELU row loop and the softmax pass. Deterministic on every
// architecture (pure IEEE f32 arithmetic) — what they are *not* is
// bit-identical to libm, which is why the fast tier is validated by the
// max-ulp and end-to-end tolerance suites in `tests/fast_tier.rs`.
// ---------------------------------------------------------------------------

/// Polynomial `e^x`: range reduction `x = k·ln2 + r` (two-part ln2,
/// `|r| ≤ ln2/2`), degree-6 Taylor core, exponent reassembled via bits.
/// Clamped to the finite f32 range; see `tests/fast_tier.rs` for the
/// pinned max-ulp bound vs libm.
#[inline]
pub fn exp_fast(x: f32) -> f32 {
    // Outside the f32-normal result range: flush to 0 / saturate to inf
    // (subnormal exp results round to a softmax weight of zero anyway).
    if x < -87.336_54 {
        return 0.0;
    }
    if x > 88.722_83 {
        return f32::INFINITY;
    }
    // Two-part ln2 split (musl's expf constants, spelled in bits so the
    // hi part's low mantissa is exactly zero and `kf * ln2_hi` is exact).
    let ln2_hi = f32::from_bits(0x3f31_7200); // 6.9314575e-1
    let ln2_lo = f32::from_bits(0x35bf_be8e); // 1.4286068e-6
    let kf = (x * std::f32::consts::LOG2_E).round();
    let r = (x - kf * ln2_hi) - kf * ln2_lo;
    // Degree-6 Taylor for e^r on |r| <= ln2/2 (truncation ~3e-8 relative).
    let c6 = 1.0 / 720.0;
    let c5 = 1.0 / 120.0;
    let c4 = 1.0 / 24.0;
    let c3 = 1.0 / 6.0;
    let p = 1.0 + r * (1.0 + r * (0.5 + r * (c3 + r * (c4 + r * (c5 + r * c6)))));
    // 2^k via exponent bits; k >= -126 holds for every x past the flush
    // threshold, but keep the subnormal split in case rounding lands -127.
    let k = kf as i32;
    if k >= -126 {
        f32::from_bits(((k + 127) as u32) << 23) * p
    } else {
        f32::from_bits(1u32 << 23) * f32::from_bits(((k + 253) as u32) << 23) * p
    }
}

/// Polynomial `tanh(x)`: odd Taylor core near zero (avoids the
/// `(e^{2x}-1)` cancellation), `(e^{2x}-1)/(e^{2x}+1)` via [`exp_fast`]
/// elsewhere, saturating to ±1 past the f32 tanh saturation point.
#[inline]
pub fn tanh_fast(x: f32) -> f32 {
    let ax = x.abs();
    if ax < 0.25 {
        let x2 = x * x;
        // tanh x = x - x³/3 + 2x⁵/15 - 17x⁷/315 + O(x⁹)
        return x * (1.0 + x2 * (-1.0 / 3.0 + x2 * (2.0 / 15.0 + x2 * (-17.0 / 315.0))));
    }
    if ax > 9.02 {
        // tanh saturates to ±1 in f32 beyond ~9.02
        return 1.0f32.copysign(x);
    }
    let e = exp_fast(2.0 * ax);
    ((e - 1.0) / (e + 1.0)).copysign(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    fn randv(g: &mut Gen, n: usize) -> Vec<f32> {
        (0..n).map(|_| g.f64_in(-2.0..2.0) as f32).collect()
    }

    fn bits_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    /// Both arms of every elementwise helper agree bitwise with the scalar
    /// loop across lengths crossing the lane width (including 0 and tails).
    #[test]
    fn elementwise_helpers_bitwise_match_scalar() {
        check("simd elementwise == scalar", 120, |g| {
            let n = g.usize_in(0..37);
            let base = randv(g, n);
            let s = randv(g, n);
            let b = randv(g, n);
            let gg = randv(g, n);
            let mu = g.f64_in(-1.0..1.0) as f32;
            let inv = g.f64_in(0.1..2.0) as f32;
            let w = g.f64_in(-1.5..1.5) as f32;

            for kernel in [Kernel::Avx2, Kernel::Portable] {
                // add_assign
                let mut want = base.clone();
                for (o, &x) in want.iter_mut().zip(&s) {
                    *o += x;
                }
                let mut got = base.clone();
                add_assign_with(kernel, &mut got, &s);
                assert!(bits_eq(&got, &want), "{kernel:?} add_assign n={n}");

                // add2_assign
                let mut want = base.clone();
                for ((xo, &pv), &bv) in want.iter_mut().zip(&s).zip(&b) {
                    *xo += pv + bv;
                }
                let mut got = base.clone();
                add2_assign_with(kernel, &mut got, &s, &b);
                assert!(bits_eq(&got, &want), "{kernel:?} add2_assign n={n}");

                // ln_apply
                let mut want = base.clone();
                for ((xo, &gv), &bv) in want.iter_mut().zip(&gg).zip(&b) {
                    *xo = (*xo - mu) * inv * gv + bv;
                }
                let mut got = base.clone();
                ln_apply_with(kernel, &mut got, &gg, &b, mu, inv);
                assert!(bits_eq(&got, &want), "{kernel:?} ln_apply n={n}");

                // axpy
                let mut want = base.clone();
                for (o, &x) in want.iter_mut().zip(&s) {
                    *o += w * x;
                }
                let mut got = base.clone();
                axpy_with(kernel, w, &s, &mut got);
                assert!(bits_eq(&got, &want), "{kernel:?} axpy n={n}");
            }
        });
    }

    #[test]
    fn active_is_stable_and_portable_is_executable() {
        assert_eq!(active(), active());
        assert_eq!(executable(Kernel::Portable), Kernel::Portable);
        if !has_avx2() {
            assert_eq!(executable(Kernel::Avx2), Kernel::Portable);
        }
    }

    /// The env-flag parse path behind `SPECMER_FORCE_PORTABLE` /
    /// `SPECMER_FAST`: recognized spellings on both sides, `None` (→ warn
    /// + fallback) for anything else.
    #[test]
    fn flag_parse_accepts_known_spellings_and_rejects_garbage() {
        for s in ["1", "true", "TRUE", "on", "Yes", " 1 "] {
            assert_eq!(parse_flag(s), Some(true), "{s:?}");
        }
        for s in ["", "0", "false", "Off", "no", " 0 "] {
            assert_eq!(parse_flag(s), Some(false), "{s:?}");
        }
        for s in ["2", "portable", "yes!", "enable", "-1"] {
            assert_eq!(parse_flag(s), None, "{s:?}");
        }
    }

    #[test]
    fn weight_dtype_parse_covers_spellings() {
        use crate::params::WeightDtype as W;
        assert_eq!(W::parse("bf16"), Some(W::Bf16));
        assert_eq!(W::parse("BFLOAT16"), Some(W::Bf16));
        assert_eq!(W::parse("f16"), Some(W::F16));
        assert_eq!(W::parse("half"), Some(W::F16));
        assert_eq!(W::parse("int8"), Some(W::Int8));
        assert_eq!(W::parse("f32"), Some(W::F32));
        assert_eq!(W::parse(""), Some(W::F32));
        assert_eq!(W::parse("fp8"), None);
        assert_eq!(W::parse("4bit"), None);
    }
}
