//! Worker-resident shared-prefix KV store with copy-on-write reuse.
//!
//! Protein-screening traffic is dominated by requests sharing an *identical
//! per-family context* (one wild-type prefix per protein), yet a cold
//! admission re-runs a full prefill — the most expensive single dispatch of
//! a request. This module caches prefilled family-context KV **per worker,
//! per model**, keyed on an exact hash of the context tokens:
//!
//! - [`PrefixStore`] — a bounded map from `context_key(tokens)` to a host
//!   KV snapshot (`Arc<Vec<f32>>`). A hit hands the `Arc` straight to
//!   `ModelBackend::prefill_into`, which attaches it copy-on-write as the
//!   sequence's committed prefix (no clone until the first decode write).
//!   Eviction is deterministic: least-recently-used by a *logical clock*
//!   bumped per lookup/insert — never wall-clock — so replays are exact.
//! - [`Residency`] — a thread-safe map of which workers currently hold
//!   which context keys, published by the stores and read by the router's
//!   soft family-affinity placement (`coordinator::router`).
//! - [`PrefixStats`] — hit/miss/eviction/byte counters exported through
//!   `/metrics` as `specmer_prefix_cache_*`.
//!
//! Determinism contract: the store's behaviour is a pure function of the
//! sequence of `lookup`/`insert` calls. Keys are exact — a hash collision
//! is resolved by comparing the stored context tokens, so a hit never
//! attaches the wrong family's KV. `debug_validate` (the
//! `SPECMER_VALIDATE=1` family) re-derives byte accounting, key integrity,
//! and capacity from first principles.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex};

/// Exact-prefix cache key: FNV-1a over the raw context token bytes.
///
/// Stable across processes (no `RandomState`), cheap, and public so the
/// router can compute the same key from a family's context when steering
/// requests toward workers that already hold it.
pub fn context_key(context: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in context {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Counters a [`PrefixStore`] exposes for `/metrics` and the benches.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Bytes currently resident (gauge, not a counter).
    pub bytes: u64,
    /// Entries currently resident (gauge).
    pub entries: u64,
}

impl PrefixStats {
    /// Combine per-store stats (e.g. a worker's draft + target stores).
    pub fn merge(self, o: PrefixStats) -> PrefixStats {
        PrefixStats {
            hits: self.hits + o.hits,
            misses: self.misses + o.misses,
            evictions: self.evictions + o.evictions,
            bytes: self.bytes + o.bytes,
            entries: self.entries + o.entries,
        }
    }
}

/// Which workers hold which context keys — the router's affinity signal.
///
/// Shared across worker threads (the stores themselves are worker-local
/// and single-threaded); publishes are best-effort hints, never load
/// bearing for correctness: a stale holder just costs one cold prefill.
#[derive(Default)]
pub struct Residency {
    map: Mutex<BTreeMap<u64, BTreeSet<usize>>>,
}

impl Residency {
    pub fn new() -> Residency {
        Residency::default()
    }

    /// Record that `worker` now holds `key` in its prefix store.
    pub fn publish(&self, key: u64, worker: usize) {
        // PANIC-OK: mutex poisoning only follows a panic elsewhere
        self.map.lock().unwrap().entry(key).or_default().insert(worker);
    }

    /// Record that `worker` evicted `key`.
    pub fn retract(&self, key: u64, worker: usize) {
        // PANIC-OK: mutex poisoning only follows a panic elsewhere
        let mut m = self.map.lock().unwrap();
        if let Some(set) = m.get_mut(&key) {
            set.remove(&worker);
            if set.is_empty() {
                m.remove(&key);
            }
        }
    }

    /// Workers currently holding `key`, in ascending id order.
    pub fn holders(&self, key: u64) -> Vec<usize> {
        // PANIC-OK: mutex poisoning only follows a panic elsewhere
        self.map
            .lock()
            .unwrap()
            .get(&key)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default()
    }
}

struct Entry {
    /// Exact context tokens — hash collisions compare against this.
    context: Vec<u8>,
    /// Host KV snapshot, shared into sequences copy-on-write.
    kv: Arc<Vec<f32>>,
    bytes: u64,
    /// Logical-clock stamp of the last hit/insert (LRU order).
    last_used: u64,
}

/// Bounded, deterministic cache of prefilled context KV snapshots.
pub struct PrefixStore {
    entries: BTreeMap<u64, Entry>,
    cap_bytes: u64,
    used_bytes: u64,
    /// Logical clock: bumped per lookup-hit/insert; drives LRU eviction.
    clock: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    /// Publish/retract target: (shared residency map, this worker's id).
    residency: Option<(Arc<Residency>, usize)>,
}

impl PrefixStore {
    pub fn new(cap_bytes: usize) -> PrefixStore {
        PrefixStore {
            entries: BTreeMap::new(),
            cap_bytes: cap_bytes as u64,
            used_bytes: 0,
            clock: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            residency: None,
        }
    }

    /// A store that mirrors its key set into a shared [`Residency`] map.
    pub fn with_residency(cap_bytes: usize, res: Arc<Residency>, worker: usize) -> PrefixStore {
        let mut s = PrefixStore::new(cap_bytes);
        s.residency = Some((res, worker));
        s
    }

    /// Exact-match lookup. A hit refreshes the entry's LRU stamp and
    /// returns the shared snapshot; a hash collision with different
    /// context tokens is a miss (never attach the wrong family's KV).
    pub fn lookup(&mut self, context: &[u8]) -> Option<Arc<Vec<f32>>> {
        let key = context_key(context);
        match self.entries.get_mut(&key) {
            Some(e) if e.context == context => {
                self.clock += 1;
                e.last_used = self.clock;
                self.hits += 1;
                Some(Arc::clone(&e.kv))
            }
            _ => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert a snapshot, evicting least-recently-used entries (ties
    /// broken by ascending key — fully deterministic) until it fits.
    /// Snapshots larger than the whole store are skipped, not cached.
    pub fn insert(&mut self, context: &[u8], kv: Arc<Vec<f32>>) {
        let bytes = (kv.len() * std::mem::size_of::<f32>()) as u64;
        if bytes > self.cap_bytes {
            return;
        }
        let key = context_key(context);
        if let Some(old) = self.entries.remove(&key) {
            // replace (same family re-published, or a key collision —
            // either way the newer snapshot wins)
            self.used_bytes -= old.bytes;
        }
        while self.used_bytes + bytes > self.cap_bytes {
            let victim = self
                .entries
                .iter()
                .min_by_key(|(k, e)| (e.last_used, **k))
                .map(|(k, _)| *k);
            let Some(vk) = victim else { break };
            // PANIC-OK: victim key was just read from the map
            let e = self.entries.remove(&vk).unwrap();
            self.used_bytes -= e.bytes;
            self.evictions += 1;
            if let Some((res, w)) = &self.residency {
                res.retract(vk, *w);
            }
        }
        self.clock += 1;
        self.entries.insert(
            key,
            Entry { context: context.to_vec(), kv, bytes, last_used: self.clock },
        );
        self.used_bytes += bytes;
        if let Some((res, w)) = &self.residency {
            res.publish(key, *w);
        }
    }

    pub fn stats(&self) -> PrefixStats {
        PrefixStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            bytes: self.used_bytes,
            entries: self.entries.len() as u64,
        }
    }

    /// Re-derive the store's invariants from first principles; part of the
    /// `SPECMER_VALIDATE=1` `debug_validate` family. Error messages name
    /// the violated invariant so seeded-corruption tests can pin them.
    pub fn debug_validate(&self) -> Result<(), String> {
        let mut sum = 0u64;
        for (k, e) in &self.entries {
            if *k != context_key(&e.context) {
                return Err(format!(
                    "prefix store key integrity: entry {k:#x} does not hash its own context"
                ));
            }
            let want = (e.kv.len() * std::mem::size_of::<f32>()) as u64;
            if e.bytes != want {
                return Err(format!(
                    "prefix store byte accounting: entry {k:#x} records {} bytes, snapshot is {want}",
                    e.bytes
                ));
            }
            if e.last_used > self.clock {
                return Err(format!(
                    "prefix store clock monotonicity: entry {k:#x} stamped {} past clock {}",
                    e.last_used, self.clock
                ));
            }
            sum += e.bytes;
        }
        if sum != self.used_bytes {
            return Err(format!(
                "prefix store byte accounting: used_bytes {} != sum of entries {sum}",
                self.used_bytes
            ));
        }
        if self.used_bytes > self.cap_bytes {
            return Err(format!(
                "prefix store capacity: used_bytes {} exceeds cap_bytes {}",
                self.used_bytes, self.cap_bytes
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(n: usize, fill: f32) -> Arc<Vec<f32>> {
        Arc::new(vec![fill; n])
    }

    #[test]
    fn exact_hit_and_miss() {
        let mut s = PrefixStore::new(1 << 20);
        assert!(s.lookup(&[1, 2, 3]).is_none());
        s.insert(&[1, 2, 3], snap(8, 0.5));
        let got = s.lookup(&[1, 2, 3]).expect("hit");
        assert_eq!(got.len(), 8);
        assert!(s.lookup(&[1, 2, 4]).is_none(), "different context misses");
        let st = s.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 2, 1));
        assert_eq!(st.bytes, 8 * 4);
    }

    #[test]
    fn lru_eviction_is_deterministic() {
        // capacity for exactly two 8-float snapshots
        let mut s = PrefixStore::new(2 * 8 * 4);
        s.insert(&[1], snap(8, 0.1));
        s.insert(&[2], snap(8, 0.2));
        // touch [1] so [2] becomes the LRU victim
        assert!(s.lookup(&[1]).is_some());
        s.insert(&[3], snap(8, 0.3));
        assert!(s.lookup(&[2]).is_none(), "LRU entry evicted");
        assert!(s.lookup(&[1]).is_some(), "recently-used entry survives");
        assert!(s.lookup(&[3]).is_some());
        assert_eq!(s.stats().evictions, 1);
        assert_eq!(s.debug_validate(), Ok(()));
    }

    #[test]
    fn oversized_snapshot_is_skipped() {
        let mut s = PrefixStore::new(16);
        s.insert(&[1], snap(8, 0.0)); // 32 bytes > 16 cap
        assert_eq!(s.stats().entries, 0);
        assert!(s.lookup(&[1]).is_none());
        assert_eq!(s.debug_validate(), Ok(()));
    }

    #[test]
    fn replacement_updates_byte_accounting() {
        let mut s = PrefixStore::new(1 << 20);
        s.insert(&[1, 2], snap(8, 0.1));
        s.insert(&[1, 2], snap(16, 0.2));
        let st = s.stats();
        assert_eq!(st.entries, 1);
        assert_eq!(st.bytes, 16 * 4);
        assert_eq!(s.lookup(&[1, 2]).unwrap().len(), 16, "newer snapshot wins");
        assert_eq!(s.debug_validate(), Ok(()));
    }

    #[test]
    fn residency_tracks_inserts_and_evictions() {
        let res = Arc::new(Residency::new());
        let mut s = PrefixStore::with_residency(8 * 4, Arc::clone(&res), 3);
        s.insert(&[1], snap(8, 0.1));
        assert_eq!(res.holders(context_key(&[1])), vec![3]);
        s.insert(&[2], snap(8, 0.2)); // evicts [1]
        assert_eq!(res.holders(context_key(&[1])), Vec::<usize>::new());
        assert_eq!(res.holders(context_key(&[2])), vec![3]);
        res.publish(context_key(&[2]), 0);
        assert_eq!(res.holders(context_key(&[2])), vec![0, 3]);
        res.retract(context_key(&[2]), 3);
        assert_eq!(res.holders(context_key(&[2])), vec![0]);
    }

    #[test]
    fn seeded_corruption_trips_validator() {
        let mut s = PrefixStore::new(1 << 20);
        s.insert(&[1, 2, 3], snap(8, 0.5));
        assert_eq!(s.debug_validate(), Ok(()));

        // corrupt the aggregate byte accounting
        let saved = s.used_bytes;
        s.used_bytes += 4;
        let err = s.debug_validate().unwrap_err();
        assert!(err.contains("byte accounting"), "got: {err}");
        s.used_bytes = saved;
        assert_eq!(s.debug_validate(), Ok(()));

        // corrupt a key (re-file the entry under a wrong hash)
        let (k, e) = s.entries.pop_first().unwrap();
        s.entries.insert(k ^ 1, e);
        let err = s.debug_validate().unwrap_err();
        assert!(err.contains("key integrity"), "got: {err}");
        let (k, e) = s.entries.pop_first().unwrap();
        s.entries.insert(k ^ 1, e);
        assert_eq!(s.debug_validate(), Ok(()));

        // corrupt capacity (shrink the cap under the resident bytes)
        let saved = s.cap_bytes;
        s.cap_bytes = 1;
        let err = s.debug_validate().unwrap_err();
        assert!(err.contains("capacity"), "got: {err}");
        s.cap_bytes = saved;
        assert_eq!(s.debug_validate(), Ok(()));

        // corrupt an entry's clock stamp past the store clock
        let stamp = s.clock + 10;
        s.entries.values_mut().next().unwrap().last_used = stamp;
        let err = s.debug_validate().unwrap_err();
        assert!(err.contains("clock monotonicity"), "got: {err}");
    }
}
