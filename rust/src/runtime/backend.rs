//! The [`ModelBackend`] abstraction the decode engines run against.
//!
//! Two implementations exist:
//!   * [`super::hlo::HloModel`] — the production path: AOT-compiled HLO
//!     programs executed via PJRT (Python never runs).
//!   * [`super::cpu_ref::CpuModel`] — a pure-Rust forward of the identical
//!     transformer, used as the parity oracle in integration tests and as
//!     a no-artifacts fallback engine.
//!
//! Shared position convention (see python/compile/model.py): `prefill`
//! feeds the first n-1 context tokens; every later committed token is fed
//! exactly once (via `generate`'s feed phase or `verify`) before sampling
//! continues. The opaque `Cache` handle carries the KV state between calls.
//!
//! `generate` is the batched draft entry point: one call feeds the pending
//! committed tokens and drafts all `c` candidate blocks. Implementations
//! must leave the cache in the post-feed (committed) state — candidate KV
//! lives in implementation-private branch state (a branched cache on the
//! CPU backend, the candidate scan inside the HLO program) and must never
//! leak into the committed cache, so that the subsequent `verify` call
//! rewrites slots from its own `pos` under the frontier convention. See
//! the `runtime` module docs for the full cache-branching contract.
//!
//! `generate_batch`/`verify_batch` are the cross-sequence lockstep entry
//! points: B independent sequences — each with its own cache, feed span,
//! uniforms and sampling params (`temp`/`top_p` only gate the per-row
//! `adjust_dist`, so they vary freely within a batch) — go through one
//! draft dispatch of `[B·c, D]` rows and one verify dispatch over the
//! union of their teacher-forced rows. The default implementations loop
//! the single-sequence calls (correct for any backend); `cpu_ref`
//! overrides them with genuinely batched dispatches. The contract either
//! way: per-sequence results must be identical to B separate
//! `generate`/`verify` calls over the same caches.

use anyhow::Result;

/// Candidate tokens + the adjusted draft distributions they were sampled
/// from (`p_i` of Algorithm 1): `tokens[c][g]`, `dists[c][g][vocab]`.
pub struct DraftBlock {
    pub tokens: Vec<Vec<u8>>,
    pub dists: Vec<Vec<Vec<f32>>>,
}

/// Adjusted target distributions at gamma+1 positions: `dists[g][vocab]`
/// (`dists[gamma]` is the bonus-token distribution).
pub struct VerifyBlock {
    pub dists: Vec<Vec<f32>>,
}

/// One sequence's slice of a lockstep draft dispatch: its own cache, the
/// committed-but-unfed tokens to feed at absolute position `pos`, the
/// `c * gamma` uniforms driving its candidate sampling, and its sampling
/// params (`temp`/`top_p` only gate the per-row `adjust_dist`, so they may
/// vary freely across a lockstep batch).
pub struct DraftSeq<'a, C> {
    pub cache: &'a mut C,
    pub feed: &'a [u8],
    pub pos: usize,
    pub u: &'a [f32],
    pub temp: f32,
    pub top_p: f32,
}

/// One sequence's slice of a lockstep verify dispatch (`toks`/`pos` follow
/// the [`ModelBackend::verify`] convention; `temp`/`top_p` are
/// per-sequence, as in [`DraftSeq`]).
pub struct VerifySeq<'a, C> {
    pub cache: &'a mut C,
    pub toks: &'a [u8],
    pub pos: usize,
    pub temp: f32,
    pub top_p: f32,
}

pub trait ModelBackend {
    /// Opaque KV-cache state.
    type Cache;

    fn maxlen(&self) -> usize;
    fn vocab(&self) -> usize;

    /// Which candidate counts the backend can draft in one call.
    fn supported_c(&self) -> Vec<usize>;
    /// Which draft lengths the backend supports.
    fn supported_gamma(&self) -> Vec<usize>;

    /// Feed the first `n-1` of `tokens` (n = tokens.len()); fresh cache.
    fn prefill(&self, tokens: &[u8]) -> Result<Self::Cache>;

    /// Feed `feed` (the committed-but-unfed tokens, at absolute positions
    /// `pos..pos+feed.len()`), then draft `gamma` tokens for each of `c`
    /// candidates using uniforms `u` (length c*gamma). Updates the cache
    /// to the post-feed (committed) state.
    #[allow(clippy::too_many_arguments)]
    fn generate(
        &self,
        cache: &mut Self::Cache,
        feed: &[u8],
        pos: usize,
        c: usize,
        gamma: usize,
        u: &[f32],
        temp: f32,
        top_p: f32,
    ) -> Result<DraftBlock>;

    /// Teacher-forced verification: `toks[0]` is the last committed-but-
    /// unfed token, `toks[1..]` the selected candidate block; `pos` is the
    /// absolute position of `toks[0]`. Updates the cache.
    fn verify(
        &self,
        cache: &mut Self::Cache,
        toks: &[u8],
        pos: usize,
        temp: f32,
        top_p: f32,
    ) -> Result<VerifyBlock>;

    /// Lockstep draft over B sequences: every sequence feeds its pending
    /// committed tokens and drafts `c` candidate blocks of `gamma` tokens
    /// in one dispatch. Only `c` and `gamma` are shared across the batch
    /// (they fix the dispatch shapes; the coordinator groups requests so
    /// they match); cache, feed span, uniforms and sampling params are
    /// per-sequence. Returns one [`DraftBlock`] per sequence, in order.
    /// Must be result-identical to looping `generate`.
    fn generate_batch(
        &self,
        seqs: &mut [DraftSeq<'_, Self::Cache>],
        c: usize,
        gamma: usize,
    ) -> Result<Vec<DraftBlock>> {
        seqs.iter_mut()
            .map(|s| self.generate(s.cache, s.feed, s.pos, c, gamma, s.u, s.temp, s.top_p))
            .collect()
    }

    /// Lockstep teacher-forced verification over B sequences; one
    /// [`VerifyBlock`] per sequence, in order. Must be result-identical to
    /// looping `verify`.
    fn verify_batch(&self, seqs: &mut [VerifySeq<'_, Self::Cache>]) -> Result<Vec<VerifyBlock>> {
        seqs.iter_mut()
            .map(|s| self.verify(s.cache, s.toks, s.pos, s.temp, s.top_p))
            .collect()
    }

    /// Per-position NLL of tokens[1..] under the raw model (no temp/top-p);
    /// index 0 is 0.0.
    fn score(&self, tokens: &[u8]) -> Result<Vec<f32>>;

    /// Mean-pooled final-hidden-state embedding (ESM2 stand-in).
    fn embed(&self, tokens: &[u8]) -> Result<Vec<f32>> {
        let _ = tokens;
        Err(anyhow::anyhow!("embed not supported by this backend"))
    }

    /// Snapshot a cache to host floats (for the scheduler's per-protein
    /// prefill cache) and restore it. Round-trip must be exact.
    fn cache_to_host(&self, cache: &Self::Cache) -> Result<Vec<f32>>;
    fn cache_from_host(&self, data: &[f32]) -> Result<Self::Cache>;
}
