//! The [`ModelBackend`] abstraction the decode engines run against.
//!
//! Two implementations exist:
//!   * [`super::hlo::HloModel`] — the production path: AOT-compiled HLO
//!     programs executed via PJRT (Python never runs).
//!   * [`super::cpu_ref::CpuModel`] — a pure-Rust forward of the identical
//!     transformer, used as the parity oracle in integration tests and as
//!     a no-artifacts fallback engine.
//!
//! Shared position convention (see python/compile/model.py): `prefill`
//! feeds the first n-1 context tokens; every later committed token is fed
//! exactly once (via `generate`'s feed phase or `verify`) before sampling
//! continues. The opaque `Cache` handle carries the KV state between calls.
//!
//! `generate` is the batched draft entry point: one call feeds the pending
//! committed tokens and drafts all `c` candidate blocks. Implementations
//! must leave the cache in the post-feed (committed) state — candidate KV
//! lives in implementation-private branch state (a branched cache on the
//! CPU backend, the candidate scan inside the HLO program) and must never
//! leak into the committed cache, so that the subsequent `verify` call
//! rewrites slots from its own `pos` under the frontier convention. See
//! the `runtime` module docs for the full cache-branching contract.
//!
//! `generate_batch`/`verify_batch` are the cross-sequence lockstep entry
//! points: B independent sequences — each with its own cache, feed span,
//! uniforms and sampling params (`temp`/`top_p` only gate the per-row
//! `adjust_dist`, so they vary freely within a batch) — go through one
//! draft dispatch of `[B·c, D]` rows and one verify dispatch over the
//! union of their teacher-forced rows. The default implementations loop
//! the single-sequence calls (correct for any backend); `cpu_ref`
//! overrides them with genuinely batched dispatches. The contract either
//! way: per-sequence results must be identical to B separate
//! `generate`/`verify` calls over the same caches.
//!
//! `draft_tree`/`verify_tree` are the shared-prefix candidate-*tree* entry
//! points: a round drafts a whole [`TokenTree`] (parent-pointer forest, node
//! ids in DFS path order, shared prefixes materialized once) and verifies
//! every node in one teacher-forced pass under an ancestor-visible
//! attention mask. The default implementations *linearize*: `draft_tree`
//! maps the tree's per-node uniforms onto a root-to-leaf chain matrix and
//! calls flat `generate` (a deterministic backend resamples identical
//! shared-prefix tokens from identical dists and uniforms, so the chains
//! fold back into the tree losslessly), and `verify_tree` teacher-forces
//! each root-to-leaf path through flat `verify`. That keeps the HLO/PJRT
//! backend and [`super::prefill_cache::PrefillCached`] working untouched;
//! `cpu_ref` overrides both with genuinely tree-shaped dispatches
//! ([`super::cpu_ref::TreeTails`]). Cache contract for `verify_tree`:
//! only the `trunk` rows (committed-but-unfed tokens) enter the committed
//! cache — candidate-node KV is round-scratch, so the *next* round's trunk
//! must re-feed every token committed since (the driver tracks this as
//! `target_fed`).

use anyhow::Result;

/// A shared-prefix candidate tree (forest): `parents[i]` is `None` for the
/// roots and otherwise a node id `< i`; `tokens[i]` is node `i`'s drafted
/// token. Node ids are in DFS path order — each root's whole subtree
/// precedes the next root — so chain-shaped trees enumerate exactly like
/// flat candidate blocks (`id = ci * gamma + gi`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TokenTree {
    pub parents: Vec<Option<usize>>,
    pub tokens: Vec<u8>,
}

impl TokenTree {
    pub fn len(&self) -> usize {
        self.parents.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parents.is_empty()
    }

    /// Per-node depth (roots are depth 0).
    pub fn depths(&self) -> Vec<usize> {
        let mut d = vec![0usize; self.parents.len()];
        for (i, p) in self.parents.iter().enumerate() {
            if let Some(p) = *p {
                d[i] = d[p] + 1;
            }
        }
        d
    }

    /// Root-to-self node ids (inclusive of `i`).
    pub fn ancestors(&self, i: usize) -> Vec<usize> {
        let mut chain = vec![i];
        let mut cur = i;
        while let Some(p) = self.parents[cur] {
            chain.push(p);
            cur = p;
        }
        chain.reverse();
        chain
    }

    /// Dense ancestor-visibility mask, row-major `[n, n]`:
    /// `mask[q * n + a]` ⇔ node `a` is an ancestor of `q` or `q` itself —
    /// exactly the positions node `q`'s attention row may see among the
    /// tree rows of a verify pass.
    pub fn ancestor_mask(&self) -> Vec<bool> {
        let n = self.parents.len();
        let mut mask = vec![false; n * n];
        for q in 0..n {
            if let Some(p) = self.parents[q] {
                let (pre, row) = mask.split_at_mut(q * n);
                row[..n].copy_from_slice(&pre[p * n..p * n + n]);
            }
            mask[q * n + q] = true;
        }
        mask
    }

    /// Node ids with no children, in id order.
    pub fn leaves(&self) -> Vec<usize> {
        let n = self.parents.len();
        let mut has_child = vec![false; n];
        for p in self.parents.iter().flatten() {
            has_child[*p] = true;
        }
        (0..n).filter(|&i| !has_child[i]).collect()
    }

    /// Root-to-leaf paths (node ids), one per leaf, in leaf order. For a
    /// chain-shaped tree, path `ci` is flat candidate `ci`'s block.
    pub fn paths(&self) -> Vec<Vec<usize>> {
        self.leaves().iter().map(|&l| self.ancestors(l)).collect()
    }

    /// The token sequence along each root-to-leaf path (what the k-mer
    /// scorer ranks).
    pub fn path_tokens(&self) -> Vec<Vec<u8>> {
        self.paths().iter().map(|p| p.iter().map(|&q| self.tokens[q]).collect()).collect()
    }

    /// Structural sanity: parents precede children, token table matches.
    pub fn validate(&self) -> Result<()> {
        if self.tokens.len() != self.parents.len() {
            anyhow::bail!(
                "TokenTree: {} tokens for {} nodes",
                self.tokens.len(),
                self.parents.len()
            );
        }
        for (i, p) in self.parents.iter().enumerate() {
            if let Some(p) = *p {
                if p >= i {
                    anyhow::bail!("TokenTree: node {i} has parent {p} (parents must precede)");
                }
            }
        }
        Ok(())
    }
}

/// Candidate tokens + the adjusted draft distributions they were sampled
/// from (`p_i` of Algorithm 1): `tokens[c][g]`, `dists[c][g][vocab]`.
pub struct DraftBlock {
    pub tokens: Vec<Vec<u8>>,
    pub dists: Vec<Vec<Vec<f32>>>,
}

/// Adjusted target distributions at gamma+1 positions: `dists[g][vocab]`
/// (`dists[gamma]` is the bonus-token distribution).
pub struct VerifyBlock {
    pub dists: Vec<Vec<f32>>,
}

/// One drafted candidate tree: `tokens[i]` / `dists[i]` are node `i`'s
/// sampled token and the adjusted draft distribution it was sampled from
/// (`p_i` of Algorithm 1 along whichever root-to-leaf path `i` lies on).
pub struct DraftTreeBlock {
    pub tokens: Vec<u8>,
    pub dists: Vec<Vec<f32>>,
}

/// Teacher-forced verification of a whole candidate tree.
pub struct VerifyTreeBlock {
    /// Adjusted target distribution after the trunk — what the root-level
    /// token is accepted against (flat `dists[0]`).
    pub root_dist: Vec<f32>,
    /// Per-node adjusted target distribution — what node `i`'s *successor*
    /// on a path is accepted against; at a leaf, the bonus distribution.
    pub dists: Vec<Vec<f32>>,
}

/// One sequence's slice of a lockstep draft dispatch: its own cache, the
/// committed-but-unfed tokens to feed at absolute position `pos`, the
/// `c * gamma` uniforms driving its candidate sampling, and its sampling
/// params (`temp`/`top_p` only gate the per-row `adjust_dist`, so they may
/// vary freely across a lockstep batch).
pub struct DraftSeq<'a, C> {
    pub cache: &'a mut C,
    pub feed: &'a [u8],
    pub pos: usize,
    pub u: &'a [f32],
    pub temp: f32,
    pub top_p: f32,
}

/// One sequence's slice of a lockstep verify dispatch (`toks`/`pos` follow
/// the [`ModelBackend::verify`] convention; `temp`/`top_p` are
/// per-sequence, as in [`DraftSeq`]).
pub struct VerifySeq<'a, C> {
    pub cache: &'a mut C,
    pub toks: &'a [u8],
    pub pos: usize,
    pub temp: f32,
    pub top_p: f32,
}

pub trait ModelBackend {
    /// Opaque KV-cache state.
    type Cache;

    fn maxlen(&self) -> usize;
    fn vocab(&self) -> usize;

    /// Which candidate counts the backend can draft in one call. Returns a
    /// borrowed slice so per-request validation never allocates.
    fn supported_c(&self) -> &[usize];
    /// Which draft lengths the backend supports (borrowed, like
    /// [`Self::supported_c`]).
    fn supported_gamma(&self) -> &[usize];

    /// Feed the first `n-1` of `tokens` (n = tokens.len()); fresh cache.
    fn prefill(&self, tokens: &[u8]) -> Result<Self::Cache>;

    /// Feed `feed` (the committed-but-unfed tokens, at absolute positions
    /// `pos..pos+feed.len()`), then draft `gamma` tokens for each of `c`
    /// candidates using uniforms `u` (length c*gamma). Updates the cache
    /// to the post-feed (committed) state.
    #[allow(clippy::too_many_arguments)]
    fn generate(
        &self,
        cache: &mut Self::Cache,
        feed: &[u8],
        pos: usize,
        c: usize,
        gamma: usize,
        u: &[f32],
        temp: f32,
        top_p: f32,
    ) -> Result<DraftBlock>;

    /// Teacher-forced verification: `toks[0]` is the last committed-but-
    /// unfed token, `toks[1..]` the selected candidate block; `pos` is the
    /// absolute position of `toks[0]`. Updates the cache.
    fn verify(
        &self,
        cache: &mut Self::Cache,
        toks: &[u8],
        pos: usize,
        temp: f32,
        top_p: f32,
    ) -> Result<VerifyBlock>;

    /// Lockstep draft over B sequences: every sequence feeds its pending
    /// committed tokens and drafts `c` candidate blocks of `gamma` tokens
    /// in one dispatch. Only `c` and `gamma` are shared across the batch
    /// (they fix the dispatch shapes; the coordinator groups requests so
    /// they match); cache, feed span, uniforms and sampling params are
    /// per-sequence. Returns one [`DraftBlock`] per sequence, in order.
    /// Must be result-identical to looping `generate`.
    fn generate_batch(
        &self,
        seqs: &mut [DraftSeq<'_, Self::Cache>],
        c: usize,
        gamma: usize,
    ) -> Result<Vec<DraftBlock>> {
        seqs.iter_mut()
            .map(|s| self.generate(s.cache, s.feed, s.pos, c, gamma, s.u, s.temp, s.top_p))
            .collect()
    }

    /// Lockstep teacher-forced verification over B sequences; one
    /// [`VerifyBlock`] per sequence, in order. Must be result-identical to
    /// looping `verify`.
    fn verify_batch(&self, seqs: &mut [VerifySeq<'_, Self::Cache>]) -> Result<Vec<VerifyBlock>> {
        seqs.iter_mut()
            .map(|s| self.verify(s.cache, s.toks, s.pos, s.temp, s.top_p))
            .collect()
    }

    /// Feed `feed` (as in [`Self::generate`]) then draft one token per node
    /// of the tree shaped by `parents` (DFS path order; see [`TokenTree`]).
    /// Node `i` samples from the adjusted distribution of its parent's row
    /// (the post-feed row for roots) using uniform `u[i]`; siblings share
    /// the parent distribution and differ only in their uniform. Updates
    /// the cache to the post-feed (committed) state; node KV is
    /// round-scratch.
    ///
    /// The default linearizes to flat [`Self::generate`] with one chain per
    /// leaf, replaying each node's uniform at its depth on every path
    /// through it — identical dist + identical uniform resample identical
    /// shared-prefix tokens on a deterministic backend, so the chains fold
    /// back into the tree without ambiguity.
    #[allow(clippy::too_many_arguments)]
    fn draft_tree(
        &self,
        cache: &mut Self::Cache,
        feed: &[u8],
        pos: usize,
        parents: &[Option<usize>],
        u: &[f32],
        temp: f32,
        top_p: f32,
    ) -> Result<DraftTreeBlock> {
        debug_assert_eq!(u.len(), parents.len());
        let shape = TokenTree { parents: parents.to_vec(), tokens: vec![0; parents.len()] };
        shape.validate()?;
        let paths = shape.paths();
        let gamma = paths.iter().map(|p| p.len()).max().unwrap_or(0);
        if paths.iter().any(|p| p.len() != gamma) {
            anyhow::bail!("draft_tree: default linearization needs equal-depth leaves");
        }
        let mut u_flat = Vec::with_capacity(paths.len() * gamma);
        for p in &paths {
            u_flat.extend(p.iter().map(|&q| u[q]));
        }
        let block = self.generate(cache, feed, pos, paths.len(), gamma, &u_flat, temp, top_p)?;
        let mut tokens = vec![0u8; parents.len()];
        let mut dists: Vec<Vec<f32>> = vec![Vec::new(); parents.len()];
        for (li, p) in paths.iter().enumerate() {
            for (d, &q) in p.iter().enumerate() {
                if dists[q].is_empty() {
                    tokens[q] = block.tokens[li][d];
                    dists[q] = block.dists[li][d].clone();
                }
            }
        }
        Ok(DraftTreeBlock { tokens, dists })
    }

    /// Teacher-force the whole tree against this model in one conceptual
    /// pass: feed `trunk` (every committed-but-unfed token, `trunk[0]` at
    /// absolute position `pos`) into the committed cache, then evaluate
    /// every tree node at position `pos + trunk.len() + depth` under an
    /// ancestor-visible attention mask. Only trunk KV persists in the
    /// cache; node KV is round-scratch, so the caller must re-feed tokens
    /// committed this round in the next trunk.
    ///
    /// The default linearizes to one flat [`Self::verify`] per root-to-leaf
    /// path (`toks = trunk ++ path`), which re-feeds the trunk each call
    /// and leaves the cache in the required trunk-fed state.
    fn verify_tree(
        &self,
        cache: &mut Self::Cache,
        trunk: &[u8],
        pos: usize,
        tree: &TokenTree,
        temp: f32,
        top_p: f32,
    ) -> Result<VerifyTreeBlock> {
        tree.validate()?;
        debug_assert!(!trunk.is_empty());
        let t = trunk.len();
        let mut root_dist = Vec::new();
        let mut dists: Vec<Vec<f32>> = vec![Vec::new(); tree.len()];
        for p in tree.paths() {
            let mut toks = trunk.to_vec();
            toks.extend(p.iter().map(|&q| tree.tokens[q]));
            let vb = self.verify(cache, &toks, pos, temp, top_p)?;
            if root_dist.is_empty() {
                root_dist = vb.dists[t - 1].clone();
            }
            for (d, &q) in p.iter().enumerate() {
                if dists[q].is_empty() {
                    dists[q] = vb.dists[t + d].clone();
                }
            }
        }
        Ok(VerifyTreeBlock { root_dist, dists })
    }

    /// Per-position NLL of tokens[1..] under the raw model (no temp/top-p);
    /// index 0 is 0.0.
    fn score(&self, tokens: &[u8]) -> Result<Vec<f32>>;

    /// Mean-pooled final-hidden-state embedding (ESM2 stand-in).
    fn embed(&self, tokens: &[u8]) -> Result<Vec<f32>> {
        let _ = tokens;
        Err(anyhow::anyhow!("embed not supported by this backend"))
    }

    /// Snapshot a cache to host floats (for the scheduler's per-protein
    /// prefill cache) and restore it. Round-trip must be exact.
    fn cache_to_host(&self, cache: &Self::Cache) -> Result<Vec<f32>>;
    fn cache_from_host(&self, data: &[f32]) -> Result<Self::Cache>;

    /// An empty cache suitable for incremental (chunked) prefill via
    /// [`Self::prefill_chunked`], or `None` if the backend only supports
    /// one-shot [`Self::prefill`] (the default — HLO keeps working and the
    /// admission machinery falls back to one-shot prefill).
    fn prefill_begin(&self) -> Option<Self::Cache> {
        None
    }

    /// Feed `toks` at absolute positions `pos..pos+toks.len()` into a cache
    /// produced by [`Self::prefill_begin`]. Splitting a prefill into chunks
    /// must be bit-identical to one-shot `prefill` over the concatenation
    /// (the CPU kernels are row-count-independent, so this holds by
    /// construction there). Callers feed the first n−1 context tokens in
    /// total, matching the `prefill` convention.
    fn prefill_chunked(&self, cache: &mut Self::Cache, toks: &[u8], pos: usize) -> Result<()> {
        let _ = (cache, toks, pos);
        Err(anyhow::anyhow!("chunked prefill not supported by this backend"))
    }

    /// Attach a shared host KV snapshot (a `runtime::prefix_store` hit) as
    /// a new sequence's committed prefix. The default materializes a copy
    /// via [`Self::cache_from_host`]; backends with copy-on-write caches
    /// override this to share the snapshot until the first decode write.
    fn prefill_into(&self, host: &std::sync::Arc<Vec<f32>>) -> Result<Self::Cache> {
        self.cache_from_host(host)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    //        0         6
    //       / \        |
    //      1   4       7
    //      |   |
    //      2   5
    //      |
    //      3
    fn two_root_tree() -> TokenTree {
        TokenTree {
            parents: vec![None, Some(0), Some(1), Some(2), Some(0), Some(4), None, Some(6)],
            tokens: vec![10, 11, 12, 13, 14, 15, 16, 17],
        }
    }

    #[test]
    fn token_tree_structure_helpers() {
        let t = two_root_tree();
        t.validate().unwrap();
        assert_eq!(t.depths(), vec![0, 1, 2, 3, 1, 2, 0, 1]);
        assert_eq!(t.ancestors(3), vec![0, 1, 2, 3]);
        assert_eq!(t.ancestors(5), vec![0, 4, 5]);
        assert_eq!(t.leaves(), vec![3, 5, 7]);
        assert_eq!(t.paths(), vec![vec![0, 1, 2, 3], vec![0, 4, 5], vec![6, 7]]);
        let want: Vec<Vec<u8>> = vec![vec![10, 11, 12, 13], vec![10, 14, 15], vec![16, 17]];
        assert_eq!(t.path_tokens(), want);
    }

    #[test]
    fn ancestor_mask_matches_parent_chains() {
        let t = two_root_tree();
        let n = t.len();
        let mask = t.ancestor_mask();
        for q in 0..n {
            let anc = t.ancestors(q);
            for a in 0..n {
                assert_eq!(mask[q * n + a], anc.contains(&a), "q={q} a={a}");
            }
        }
    }

    #[test]
    fn token_tree_rejects_forward_parents() {
        let t = TokenTree { parents: vec![Some(1), None], tokens: vec![0, 0] };
        assert!(t.validate().is_err());
        let t = TokenTree { parents: vec![None, Some(0)], tokens: vec![0] };
        assert!(t.validate().is_err());
    }
}
