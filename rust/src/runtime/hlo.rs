//! [`ModelBackend`] over AOT-compiled HLO programs (the production path).
//!
//! One `HloModel` owns the flat parameter literal for a checkpoint plus a
//! shared [`Runtime`]; each call builds the small input literals, executes
//! the corresponding artifact, and unpacks the output tuple.

use std::sync::Arc;

use anyhow::{anyhow, Result};
use xla::Literal;

use super::backend::{DraftBlock, ModelBackend, VerifyBlock};
use super::client::{lit_f32, lit_i32, scalar_f32, scalar_i32, tokens_literal, Arg, Runtime};
use crate::params::{load_model, ModelDims};

pub struct HloModel {
    pub name: String,
    pub dims: ModelDims,
    rt: Arc<Runtime>,
    /// Flat parameter vector, resident on device (uploaded once at load —
    /// saves a ~1.4 MB host->device copy per dispatch; EXPERIMENTS.md §Perf).
    params_buf: xla::PjRtBuffer,
    vocab: usize,
    supported_c: Vec<usize>,
    supported_g: Vec<usize>,
}

impl HloModel {
    /// Load checkpoint `name` ("draft" / "target" / "xl") from artifacts.
    pub fn load(rt: Arc<Runtime>, artifacts: &std::path::Path, name: &str) -> Result<HloModel> {
        let mp = load_model(artifacts, name)?;
        let manifest = crate::params::load_manifest(artifacts)?;
        let params_buf = rt.to_device_f32(&mp.flat, &[mp.flat.len()])?;
        // discover which (c, gamma) variants were exported
        let mut cs = vec![];
        let mut gs = vec![];
        for c in [1usize, 2, 3, 5, 8] {
            if rt.has_program(&format!("{name}_generate_c{c}_g5"))
                || rt.has_program(&format!("{name}_generate_c{c}_g16"))
            {
                cs.push(c);
            }
        }
        for g in [1usize, 5, 10, 15, 16] {
            if rt.has_program(&format!("{name}_generate_c1_g{g}")) {
                gs.push(g);
            }
        }
        Ok(HloModel {
            name: name.to_string(),
            dims: mp.dims,
            rt,
            params_buf,
            vocab: manifest.vocab,
            supported_c: cs,
            supported_g: gs,
        })
    }

    fn cache_dims(&self) -> Vec<i64> {
        self.dims.cache_shape.iter().map(|&d| d as i64).collect()
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl ModelBackend for HloModel {
    type Cache = Literal;

    fn maxlen(&self) -> usize {
        self.dims.maxlen()
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn supported_c(&self) -> &[usize] {
        &self.supported_c
    }
    fn supported_gamma(&self) -> &[usize] {
        &self.supported_g
    }

    fn prefill(&self, tokens: &[u8]) -> Result<Literal> {
        let s = self.maxlen();
        let toks = tokens_literal(tokens, s)?;
        let n = scalar_i32(tokens.len() as i32);
        let mut out = self.rt.run_args(
            &format!("{}_prefill", self.name),
            &[Arg::Buf(&self.params_buf), Arg::Lit(&toks), Arg::Lit(&n)],
        )?;
        Ok(out.remove(0))
    }

    fn generate(
        &self,
        cache: &mut Literal,
        feed: &[u8],
        pos: usize,
        c: usize,
        gamma: usize,
        u: &[f32],
        temp: f32,
        top_p: f32,
    ) -> Result<DraftBlock> {
        debug_assert_eq!(u.len(), c * gamma);
        debug_assert!(!feed.is_empty() && feed.len() <= gamma + 1);
        let prog = format!("{}_generate_c{c}_g{gamma}", self.name);
        let mut feed_pad = vec![0i32; gamma + 1];
        for (i, &t) in feed.iter().enumerate() {
            feed_pad[i] = t as i32;
        }
        let feed_lit = lit_i32(&feed_pad, &[(gamma + 1) as i64])?;
        let n_feed = scalar_i32(feed.len() as i32);
        let pos_lit = scalar_i32(pos as i32);
        let u_lit = lit_f32(u, &[c as i64, gamma as i64])?;
        let temp_l = scalar_f32(temp);
        let top_p_l = scalar_f32(top_p);
        let out = self.rt.run_args(
            &prog,
            &[
                Arg::Buf(&self.params_buf),
                Arg::Lit(cache),
                Arg::Lit(&feed_lit),
                Arg::Lit(&n_feed),
                Arg::Lit(&pos_lit),
                Arg::Lit(&u_lit),
                Arg::Lit(&temp_l),
                Arg::Lit(&top_p_l),
            ],
        )?;
        let mut it = out.into_iter();
        let toks_l = it.next().ok_or_else(|| anyhow!("missing toks output"))?;
        let dists_l = it.next().ok_or_else(|| anyhow!("missing dists output"))?;
        let cache_l = it.next().ok_or_else(|| anyhow!("missing cache output"))?;
        *cache = cache_l;

        let toks_flat = toks_l.to_vec::<i32>()?;
        let dists_flat = dists_l.to_vec::<f32>()?;
        let v = self.vocab;
        let tokens = (0..c)
            .map(|ci| (0..gamma).map(|g| toks_flat[ci * gamma + g] as u8).collect())
            .collect();
        let dists = (0..c)
            .map(|ci| {
                (0..gamma)
                    .map(|g| {
                        let base = (ci * gamma + g) * v;
                        dists_flat[base..base + v].to_vec()
                    })
                    .collect()
            })
            .collect();
        Ok(DraftBlock { tokens, dists })
    }

    fn verify(
        &self,
        cache: &mut Literal,
        toks: &[u8],
        pos: usize,
        temp: f32,
        top_p: f32,
    ) -> Result<VerifyBlock> {
        let gamma = toks.len() - 1;
        let prog = format!("{}_verify_g{gamma}", self.name);
        let toks_i: Vec<i32> = toks.iter().map(|&t| t as i32).collect();
        let toks_lit = lit_i32(&toks_i, &[toks.len() as i64])?;
        let pos_l = scalar_i32(pos as i32);
        let temp_l = scalar_f32(temp);
        let top_p_l = scalar_f32(top_p);
        let out = self.rt.run_args(
            &prog,
            &[
                Arg::Buf(&self.params_buf),
                Arg::Lit(cache),
                Arg::Lit(&toks_lit),
                Arg::Lit(&pos_l),
                Arg::Lit(&temp_l),
                Arg::Lit(&top_p_l),
            ],
        )?;
        let mut it = out.into_iter();
        let dists_l = it.next().ok_or_else(|| anyhow!("missing dists output"))?;
        let cache_l = it.next().ok_or_else(|| anyhow!("missing cache output"))?;
        *cache = cache_l;
        let flat = dists_l.to_vec::<f32>()?;
        let v = self.vocab;
        let dists = (0..=gamma).map(|i| flat[i * v..(i + 1) * v].to_vec()).collect();
        Ok(VerifyBlock { dists })
    }

    fn score(&self, tokens: &[u8]) -> Result<Vec<f32>> {
        let s = self.maxlen();
        let toks = tokens_literal(tokens, s)?;
        let n = scalar_i32(tokens.len().min(s) as i32);
        let out = self.rt.run_args(
            &format!("{}_score", self.name),
            &[Arg::Buf(&self.params_buf), Arg::Lit(&toks), Arg::Lit(&n)],
        )?;
        Ok(out[0].to_vec::<f32>()?[..tokens.len().min(s)].to_vec())
    }

    fn cache_to_host(&self, cache: &Literal) -> Result<Vec<f32>> {
        Ok(cache.to_vec::<f32>()?)
    }

    fn cache_from_host(&self, data: &[f32]) -> Result<Literal> {
        lit_f32(data, &self.cache_dims())
    }

    fn embed(&self, tokens: &[u8]) -> Result<Vec<f32>> {
        let s = self.maxlen();
        let toks = tokens_literal(tokens, s)?;
        let n = scalar_i32(tokens.len().min(s) as i32);
        let out = self.rt.run_args(
            &format!("{}_embed", self.name),
            &[Arg::Buf(&self.params_buf), Arg::Lit(&toks), Arg::Lit(&n)],
        )?;
        Ok(out[0].to_vec::<f32>()?)
    }
}

/// The exported k-mer Pallas kernel (TPU deployment path; the Rust-native
/// scorer in `kmer::score` is the CPU hot path — tests assert equality).
pub struct HloKmerScorer {
    rt: Arc<Runtime>,
}

impl HloKmerScorer {
    pub fn new(rt: Arc<Runtime>) -> HloKmerScorer {
        HloKmerScorer { rt }
    }

    /// Score up to 8 candidate blocks of length gamma (5/10/15).
    pub fn score(
        &self,
        table: &crate::kmer::KmerTable,
        cands: &[Vec<u8>],
        gamma: usize,
        ks: crate::kmer::KmerSet,
    ) -> Result<Vec<f32>> {
        let c_max = 8usize;
        let mut flat = vec![0i32; c_max * gamma];
        for (i, cand) in cands.iter().enumerate().take(c_max) {
            for (j, &t) in cand.iter().enumerate().take(gamma) {
                flat[i * gamma + j] = t as i32;
            }
        }
        let cands_l = lit_i32(&flat, &[c_max as i64, gamma as i64])?;
        let p1 = lit_f32(&table.p1, &[table.p1.len() as i64])?;
        let p3 = lit_f32(&table.p3, &[table.p3.len() as i64])?;
        let p5 = lit_f32(&table.p5, &[table.p5.len() as i64])?;
        let kmask = lit_f32(
            &[
                if ks.k1 { 1.0 } else { 0.0 },
                if ks.k3 { 1.0 } else { 0.0 },
                if ks.k5 { 1.0 } else { 0.0 },
            ],
            &[3],
        )?;
        let out = self.rt.run(
            &format!("kmer_score_c8_g{gamma}"),
            &[&cands_l, &p1, &p3, &p5, &kmask],
        )?;
        Ok(out[0].to_vec::<f32>()?[..cands.len().min(c_max)].to_vec())
    }
}
