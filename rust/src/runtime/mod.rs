//! Runtime layer: PJRT client wrapper, HLO-backed and pure-Rust model
//! backends, and the GEMM kernels the pure-Rust path runs on. See
//! DESIGN.md §2. Unsafe kernel code and the layer's determinism contract
//! follow docs/unsafe-policy.md, enforced by `make lint-specmer`.
//!
//! # Cache and batching conventions
//!
//! Every backend shares one position convention (see python/compile/model.py
//! and [`backend::ModelBackend`]): `prefill` feeds the first n−1 context
//! tokens; each later committed token is fed exactly once (by `generate`'s
//! feed phase or by `verify`) before sampling continues. KV caches are flat
//! `[L, 2, H, S, Dh]`, and slots at positions ≥ the committed frontier are
//! scratch — unobservable until rewritten — which is what makes the
//! branching scheme below sound.
//!
//! ## Branched drafting (`cpu_ref::BranchedCache`)
//!
//! A draft round must explore `c` candidate continuations of the same
//! committed prefix. The seed implementation cloned the entire cache per
//! candidate per round; the runtime now branches instead:
//!
//!   * the committed prefix (`0..base_len`) is **shared read-only** by all
//!     candidates — it is physically the committed `CpuCache`;
//!   * each candidate owns a **γ-slot scratch tail** (flat
//!     `[L, 2, C, H, γ, Dh]`, slot `s` ↔ absolute position `base_len + s`),
//!     written as its tokens are drafted and discarded with the round.
//!
//! Candidate tails never touch the committed cache, so the verify step sees
//! exactly the frontier convention it expects, and no KV bytes are copied
//! to branch.
//!
//! ## Batched forward and the SIMD compute tiers
//!
//! All `c` candidate rows of a draft step — and all `G` positions of a
//! teacher-forced block — go through each projection, the MLP and the
//! weight-tied logits head as single `[B,D]×[D,N]` calls into [`gemm`].
//! The kernels are **runtime-dispatched SIMD** (see [`simd`]): an explicit
//! AVX2 arm (register-tiled micro-kernel) on machines that support it, and
//! a portable chunked-lane arm that is the same code path on every other
//! architecture (`SPECMER_FORCE_PORTABLE` pins it for CI). Large shapes
//! row-parallelize over the persistent `util::threadpool::compute_pool`
//! instead of spawning threads per call.
//!
//! **Prepacked weights:** the weight-tied logits head used to run a
//! per-vocab-entry transposed dot product (`gemm::matmul_nt`) that no
//! column-vectorized kernel could serve. `CpuModel` now transposes the
//! tied embedding once at model load into an exact-width `[D, V]` panel
//! (`params::PackedWeights`; the kernels' scalar column tails handle a
//! non-lane-multiple vocab), so the head shares the projection kernels.
//!
//! **Quantized weight panels:** decode is memory-bandwidth-bound on weight
//! traffic, so every weight matrix the GEMMs read — the logits head panel
//! *and* the per-layer QKV/out/MLP matrices — is stored as a dtype-tagged
//! `params::Panel` (`f32` | `bf16` | `f16` | `int8`+per-row-scales),
//! quantized once at model load and selected by `SPECMER_WEIGHT_DTYPE`.
//! The kernels dequantize **in register** inside the inner loop
//! (shift-widen for bf16, `vcvtph2ps` for f16, `cvtepi8`+scale broadcast
//! for int8), so narrow weights never round-trip through an f32 buffer.
//! Activations, accumulators, KV cache and outputs stay f32 throughout.
//!
//! **Compute tiers and what each guarantees:**
//!
//!   * **Default f32 tier (bitwise-pinned):** lanes run across
//!     *independent output columns* while each output element accumulates
//!     over the shared `k` dimension strictly in index order with a single
//!     accumulator, and every multiply-accumulate is a separate IEEE mul
//!     then add (never FMA). Vectorization only reorders work across
//!     elements, never within one — batched results are bitwise identical
//!     to the seed scalar path (kept as `cpu_ref::reference`;
//!     `tests/cpu_batched_equivalence.rs` and `tests/kernel_equivalence.rs`
//!     enforce the equivalence). Reductions with one serial accumulator
//!     (LN statistics, attention QK dots, softmax normalizers) and
//!     transcendentals (`tanh`, `exp`) stay scalar for the same reason —
//!     see the [`simd`] module docs.
//!   * **Narrow dtypes (bitwise-pinned per dtype, not vs f32):** bf16/f16
//!     dequant is exact and int8's scale fold is ordered identically in
//!     both kernel arms, so for a fixed dtype the AVX2 arm, the portable
//!     arm, and a dequantize-then-f32-matmul oracle agree bitwise
//!     (`tests/quantization.rs`). Results differ from the f32 tier only by
//!     the one-time storage rounding.
//!   * **`SPECMER_FAST=1` (accuracy-bounded):** opts the GEMM inner loops
//!     into hardware FMA and softmax/GELU into polynomial `exp`/`tanh`
//!     ([`simd::exp_fast`]/[`simd::tanh_fast`]). This tier is deliberately
//!     *off* the bitwise contract; `tests/fast_tier.rs` bounds it by
//!     per-kernel max-ulp and end-to-end logit-delta/acceptance-rate
//!     tolerances instead.
//!
//! ## Cross-sequence lockstep (`generate_batch` / `verify_batch`)
//!
//! The serving path extends the same row-union idea across *requests*: B
//! sequences of one family run each decode round together. Per-sequence
//! state (cache slot, feed span, uniforms, `temp`/`top_p`) is carried by
//! [`backend::DraftSeq`]/[`backend::VerifySeq`] views; `cpu_ref` executes
//! the round as a ragged `[ΣG_b, D]` feed, γ−1 `[B·c, D]` arena steps over
//! a sequence-slot cache arena, and a ragged verify. Because every kernel
//! is row-independent, a sequence's tokens are bitwise-identical to a solo
//! decode with the same seed — `tests/batch_decode_equivalence.rs` pins
//! this end to end. Backends without a batched implementation inherit
//! serial-loop defaults, so lockstep serving degrades gracefully (the HLO
//! backend currently loops; batched HLO programs are an open item).
//!
//! ## Tree-structured speculation (`draft_tree` / `verify_tree`)
//!
//! A round may draft a shared-prefix candidate *tree* instead of `c`
//! independent chains (see [`backend::TokenTree`] and `decode::spec`):
//! each node's KV is stored exactly once in a parent-pointer node table
//! (`cpu_ref::TreeTails`, flat `[L, 2, N, H, Dh]`, slot = node id), so a
//! prefix shared by many candidate blocks is computed and cached once.
//! Drafting walks the tree level by level (one `[F_d, D]` dispatch per
//! depth); verification teacher-forces every node in one tree-masked
//! ragged `[N, D]` forward where a node row attends the committed prefix
//! plus its gathered root-to-self ancestor rows — the ancestor-visible
//! mask realized as a contiguous K/V gather feeding the same two-segment
//! `attend_one` the branched caches use. With branching disabled the tree
//! degenerates to chains whose node ids, uniforms and row order coincide
//! with the flat path, so results stay bitwise identical
//! (`tests/tree_speculation.rs` pins this; backends without a native tree
//! implementation inherit defaults that linearize to the flat calls).
//!
//! ## Shared-prefix KV reuse (`prefix_store`)
//!
//! Admissions whose context was already prefilled on this worker skip the
//! prefill forward entirely: [`prefix_store::PrefixStore`] caches host KV
//! snapshots per context (bounded, logical-clock LRU, exact-match keys),
//! and `ModelBackend::prefill_into` attaches a snapshot copy-on-write as a
//! new sequence's committed prefix. Cold long contexts instead chunk their
//! residual prefill across lockstep round boundaries via
//! `ModelBackend::prefill_chunked` (see `decode::spec`'s admission state
//! machine), so neither path stalls resident batchmates.

pub mod backend;
pub mod client;
pub mod cpu_ref;
pub mod gemm;
pub mod hlo;
pub mod prefill_cache;
pub mod prefix_store;
pub mod simd;

pub use backend::{
    DraftBlock, DraftSeq, DraftTreeBlock, ModelBackend, TokenTree, VerifyBlock, VerifySeq,
    VerifyTreeBlock,
};
pub use client::Runtime;
pub use cpu_ref::CpuModel;
pub use hlo::{HloKmerScorer, HloModel};
pub use prefix_store::{context_key, PrefixStats, PrefixStore, Residency};
