//! Runtime layer: PJRT client wrapper, HLO-backed and pure-Rust model
//! backends. See DESIGN.md §2.

pub mod backend;
pub mod client;
pub mod cpu_ref;
pub mod hlo;
pub mod prefill_cache;

pub use backend::{DraftBlock, ModelBackend, VerifyBlock};
pub use client::Runtime;
pub use cpu_ref::CpuModel;
pub use hlo::{HloKmerScorer, HloModel};
