//! PJRT runtime wrapper over the `xla` crate.
//!
//! Loads HLO *text* artifacts (see aot.py for why text, not protos),
//! compiles them once on the CPU PJRT client, and exposes a typed
//! `run(args) -> Vec<Literal>` with helpers for building f32/i32 literals.
//! Executables are compiled lazily and cached by artifact name.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
// lint:allow(nondeterminism): compile-timing metrics site (compile_log only).
use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// An execute argument: either a persistent device buffer (uploaded once,
/// e.g. model parameters) or a host literal uploaded for this call.
pub enum Arg<'a> {
    Buf(&'a PjRtBuffer),
    Lit(&'a Literal),
}

/// Lazily-compiling program cache over one PJRT client.
///
/// Interior state is `Mutex`-guarded (not `RefCell`) so the runtime can be
/// shared across worker threads behind an `Arc` — `SpecOptions` carries an
/// `Arc<Runtime>` into lockstep workers.
pub struct Runtime {
    client: PjRtClient,
    hlo_dir: PathBuf,
    programs: Mutex<BTreeMap<String, PjRtLoadedExecutable>>,
    /// (name, compile_seconds) log for EXPERIMENTS.md §Perf.
    pub compile_log: Mutex<Vec<(String, f64)>>,
}

impl Runtime {
    /// `artifacts_dir` is the directory produced by `make artifacts`.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let hlo_dir = artifacts_dir.join("hlo");
        if !hlo_dir.is_dir() {
            return Err(anyhow!(
                "{} not found — run `make artifacts` first",
                hlo_dir.display()
            ));
        }
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            hlo_dir,
            programs: Mutex::new(BTreeMap::new()),
            compile_log: Mutex::new(Vec::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// True if the artifact exists on disk.
    pub fn has_program(&self, name: &str) -> bool {
        self.hlo_dir.join(format!("{name}.hlo.txt")).is_file()
    }

    fn compile(&self, name: &str) -> Result<()> {
        let path = self.hlo_dir.join(format!("{name}.hlo.txt"));
        // lint:allow(nondeterminism): compile-timing metrics site — the wall
        // clock feeds compile_log (EXPERIMENTS.md §Perf), never decode state.
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.compile_log.lock().unwrap().push((name.to_string(), dt));
        crate::debug!("compiled {name} in {dt:.2}s");
        self.programs.lock().unwrap().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute program `name` with the given literals; returns the
    /// decomposed output tuple (all exported programs return tuples).
    pub fn run(&self, name: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        if !self.programs.lock().unwrap().contains_key(name) {
            self.compile(name)?;
        }
        let programs = self.programs.lock().unwrap();
        let exe = programs.get(name).unwrap();
        let outs = exe
            .execute::<&Literal>(args)
            .with_context(|| format!("executing {name}"))?;
        let tuple = outs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        Ok(tuple.to_tuple()?)
    }

    /// Number of compiled programs (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.programs.lock().unwrap().len()
    }

    /// Upload host data to a persistent device buffer (perf: model params
    /// are uploaded once per process instead of once per dispatch — see
    /// EXPERIMENTS.md §Perf).
    pub fn to_device_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Execute with mixed buffer/literal arguments (literals are uploaded
    /// for this call only). Returns the decomposed output tuple.
    pub fn run_args(&self, name: &str, args: &[Arg]) -> Result<Vec<Literal>> {
        if !self.programs.lock().unwrap().contains_key(name) {
            self.compile(name)?;
        }
        // upload literal args; keep them alive for the call
        let temps: Vec<Option<PjRtBuffer>> = args
            .iter()
            .map(|a| match a {
                Arg::Buf(_) => Ok(None),
                Arg::Lit(l) => Ok(Some(self.client.buffer_from_host_literal(None, l)?)),
            })
            .collect::<Result<_>>()?;
        let bufs: Vec<&PjRtBuffer> = args
            .iter()
            .zip(&temps)
            .map(|(a, t)| match a {
                Arg::Buf(b) => *b,
                Arg::Lit(_) => t.as_ref().unwrap(),
            })
            .collect();
        let programs = self.programs.lock().unwrap();
        let exe = programs.get(name).unwrap();
        let outs = exe
            .execute_b::<&PjRtBuffer>(&bufs)
            .with_context(|| format!("executing {name} (buffers)"))?;
        let tuple = outs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        Ok(tuple.to_tuple()?)
    }
}

// ---- literal helpers -------------------------------------------------------

pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    debug_assert_eq!(n as usize, data.len());
    Ok(Literal::vec1(data).reshape(dims)?)
}

pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    debug_assert_eq!(n as usize, data.len());
    Ok(Literal::vec1(data).reshape(dims)?)
}

pub fn scalar_f32(x: f32) -> Literal {
    Literal::scalar(x)
}

pub fn scalar_i32(x: i32) -> Literal {
    Literal::scalar(x)
}

/// Tokens (u8) -> padded i32 literal of length `len`.
pub fn tokens_literal(tokens: &[u8], len: usize) -> Result<Literal> {
    let mut v = vec![0i32; len];
    for (i, &t) in tokens.iter().take(len).enumerate() {
        v[i] = t as i32;
    }
    lit_i32(&v, &[len as i64])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let l = lit_i32(&[5, -7], &[2]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![5, -7]);
    }

    #[test]
    fn tokens_padded() {
        let l = tokens_literal(&[3, 4, 5], 6).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![3, 4, 5, 0, 0, 0]);
    }

    #[test]
    fn missing_artifacts_dir_errors() {
        assert!(Runtime::new(Path::new("/nonexistent/path")).is_err());
    }
}
