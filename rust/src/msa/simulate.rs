//! Rust-native protein-family simulator.
//!
//! Mirrors the structure of `python/compile/data.py` (motif blocks with a
//! dominant residue + variable linkers) without trying to match its exact
//! random stream — this generator serves tests, extra workloads, and the
//! no-artifacts fallback engine; the canonical MSAs used by experiments are
//! the ones data.py bakes into artifacts/.

use super::Msa;
use crate::tokenizer::{AA, N_AA};
use crate::util::rng::Pcg64;

/// Per-column categorical profile over the 20 amino acids.
#[derive(Clone, Debug)]
pub struct Profile {
    pub cols: Vec<[f64; N_AA]>,
    pub conservation: Vec<f64>,
}

/// Rough natural AA background (matches data.py's BACKGROUND).
pub const BACKGROUND: [f64; N_AA] = [
    0.0826, 0.0137, 0.0546, 0.0672, 0.0386, 0.0708, 0.0227, 0.0593, 0.0581,
    0.0965, 0.0241, 0.0406, 0.0474, 0.0393, 0.0553, 0.0660, 0.0535, 0.0686,
    0.0110, 0.0292,
];

impl Profile {
    /// Alternating motif/linker blocks, as in data.py::make_profile.
    pub fn generate(rng: &mut Pcg64, length: usize) -> Profile {
        let mut cols = Vec::with_capacity(length);
        let mut conservation = Vec::with_capacity(length);
        let mut motif = rng.next_f64() < 0.5;
        let mut pos = 0;
        while pos < length {
            let block = if motif { 4 + rng.below(8) } else { 3 + rng.below(7) };
            let block = block.min(length - pos);
            for _ in 0..block {
                let mut col = [0f64; N_AA];
                if motif {
                    let dom = rng.below(N_AA);
                    let w = 0.60 + 0.35 * rng.next_f64();
                    for (i, c) in col.iter_mut().enumerate() {
                        *c = (1.0 - w) * (BACKGROUND[i] + 0.02);
                    }
                    col[dom] += w;
                    conservation.push(w);
                } else {
                    for (i, c) in col.iter_mut().enumerate() {
                        *c = BACKGROUND[i] * (0.3 + rng.next_f64());
                    }
                    conservation.push(0.1 + 0.2 * rng.next_f64());
                }
                let s: f64 = col.iter().sum();
                col.iter_mut().for_each(|x| *x /= s);
                cols.push(col);
            }
            pos += block;
            motif = !motif;
        }
        Profile { cols, conservation }
    }

    pub fn len(&self) -> usize {
        self.cols.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cols.is_empty()
    }

    /// Consensus (argmax per column) as a protein string.
    pub fn consensus(&self) -> String {
        self.cols
            .iter()
            .map(|col| {
                let (i, _) = col
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap();
                AA[i] as char
            })
            .collect()
    }

    /// Sample one homolog (optionally with gap noise away from motifs).
    pub fn sample(&self, rng: &mut Pcg64, gap_rate: f64) -> String {
        self.cols
            .iter()
            .zip(&self.conservation)
            .map(|(col, &cons)| {
                if gap_rate > 0.0 && rng.next_f64() < gap_rate * (1.0 - cons) {
                    '-'
                } else {
                    AA[rng.categorical(col)] as char
                }
            })
            .collect()
    }

    /// Log-probability of an (ungapped, full-length) sequence under the
    /// profile with `eps` smoothing — used by the pLDDT proxy.
    pub fn log_odds(&self, toks: &[u8], eps: f64) -> Vec<f64> {
        toks.iter()
            .enumerate()
            .map(|(i, &t)| {
                if i >= self.cols.len() {
                    return 0.0;
                }
                let a = t.wrapping_sub(crate::tokenizer::AA_OFFSET) as usize;
                let p = if a < N_AA { self.cols[i][a] } else { eps };
                let bg = if a < N_AA { BACKGROUND[a] } else { eps };
                ((p + eps) / (bg + eps)).ln()
            })
            .collect()
    }
}

/// Generate a complete synthetic family (profile + MSA).
pub fn generate_family(name: &str, length: usize, depth: usize, seed: u64) -> (Profile, Msa) {
    let mut rng = Pcg64::new(seed);
    let prof = Profile::generate(&mut rng, length);
    let wt = prof.consensus();
    let rows = (0..depth).map(|_| prof.sample(&mut rng, 0.02)).collect();
    (
        prof,
        Msa { name: name.to_string(), wild_type: wt, rows },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn profile_columns_normalized() {
        let mut rng = Pcg64::new(1);
        let p = Profile::generate(&mut rng, 120);
        assert_eq!(p.len(), 120);
        for col in &p.cols {
            let s: f64 = col.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn family_shapes() {
        let (prof, msa) = generate_family("T", 80, 50, 3);
        assert_eq!(prof.len(), 80);
        assert_eq!(msa.depth(), 50);
        assert_eq!(msa.wild_type.len(), 80);
        assert_eq!(msa.width(), 80);
    }

    #[test]
    fn consensus_scores_higher_than_random() {
        let (prof, msa) = generate_family("T", 100, 10, 7);
        let wt_toks = crate::tokenizer::encode(&msa.wild_type);
        let wt_lo: f64 = prof.log_odds(&wt_toks, 1e-6).iter().sum();
        let mut rng = Pcg64::new(99);
        let rand_seq: Vec<u8> = (0..100)
            .map(|_| crate::tokenizer::AA_OFFSET + rng.below(N_AA) as u8)
            .collect();
        let rand_lo: f64 = prof.log_odds(&rand_seq, 1e-6).iter().sum();
        assert!(wt_lo > rand_lo, "wt {wt_lo} rand {rand_lo}");
    }

    #[test]
    fn homologs_correlate_with_profile() {
        check("homolog log-odds beats random", 20, |g| {
            let seed = g.u64();
            let (prof, msa) = generate_family("T", 60, 5, seed);
            let mut rng = Pcg64::new(seed ^ 1);
            for row in &msa.rows {
                let toks = crate::tokenizer::encode(row);
                if toks.len() != 60 {
                    continue; // row had gaps; positions shift — skip
                }
                let h: f64 = prof.log_odds(&toks, 1e-6).iter().sum();
                let rand_seq: Vec<u8> = (0..60)
                    .map(|_| crate::tokenizer::AA_OFFSET + rng.below(N_AA) as u8)
                    .collect();
                let r: f64 = prof.log_odds(&rand_seq, 1e-6).iter().sum();
                assert!(h > r, "homolog {h} random {r}");
            }
        });
    }
}
