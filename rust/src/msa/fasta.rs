//! FASTA / A2M parsing and writing.
//!
//! A2M is FASTA whose sequences may contain gap characters ('-', '.') and
//! mixed case; we preserve the raw aligned strings so column statistics can
//! be computed, and expose ungapped views for tokenization.

use std::fs;
use std::io::Write;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub struct Record {
    pub id: String,
    pub seq: String,
}

impl Record {
    /// Aligned sequence with gaps removed (upper-cased).
    pub fn ungapped(&self) -> String {
        self.seq
            .chars()
            .filter(|&c| c != '-' && c != '.')
            .map(|c| c.to_ascii_uppercase())
            .collect()
    }
}

#[derive(Debug)]
pub enum FastaError {
    Io { path: String, source: std::io::Error },
    DataBeforeHeader(usize),
    Empty,
}

impl std::fmt::Display for FastaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FastaError::Io { path, source } => write!(f, "io error reading {path}: {source}"),
            FastaError::DataBeforeHeader(line) => {
                write!(f, "malformed fasta at line {line}: sequence data before first header")
            }
            FastaError::Empty => write!(f, "empty fasta file"),
        }
    }
}

impl std::error::Error for FastaError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FastaError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// Parse FASTA/A2M text into records.
pub fn parse(text: &str) -> Result<Vec<Record>, FastaError> {
    let mut out: Vec<Record> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(hdr) = line.strip_prefix('>') {
            out.push(Record { id: hdr.split_whitespace().next().unwrap_or("").to_string(), seq: String::new() });
        } else {
            match out.last_mut() {
                Some(rec) => rec.seq.push_str(line.trim()),
                None => return Err(FastaError::DataBeforeHeader(lineno + 1)),
            }
        }
    }
    if out.is_empty() {
        return Err(FastaError::Empty);
    }
    Ok(out)
}

pub fn read_path(path: &Path) -> Result<Vec<Record>, FastaError> {
    let text = fs::read_to_string(path).map_err(|e| FastaError::Io {
        path: path.display().to_string(),
        source: e,
    })?;
    parse(&text)
}

/// Write records as FASTA (60-column wrapped).
pub fn write_path(path: &Path, records: &[Record]) -> std::io::Result<()> {
    let mut f = fs::File::create(path)?;
    for r in records {
        writeln!(f, ">{}", r.id)?;
        for chunk in r.seq.as_bytes().chunks(60) {
            f.write_all(chunk)?;
            writeln!(f)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let recs = parse(">a desc\nACDE\nFGH\n>b\nKL-M\n").unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].id, "a");
        assert_eq!(recs[0].seq, "ACDEFGH");
        assert_eq!(recs[1].ungapped(), "KLM");
    }

    #[test]
    fn rejects_headerless() {
        assert!(matches!(parse("ACDE\n"), Err(FastaError::DataBeforeHeader(1))));
        assert!(matches!(parse(""), Err(FastaError::Empty)));
    }

    #[test]
    fn roundtrip_via_file() {
        let dir = std::env::temp_dir().join("specmer_fasta_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.fa");
        let recs = vec![
            Record { id: "x".into(), seq: "A".repeat(130) },
            Record { id: "y".into(), seq: "KLM-NP".into() },
        ];
        write_path(&p, &recs).unwrap();
        let back = read_path(&p).unwrap();
        assert_eq!(back, recs);
    }

    #[test]
    fn lowercase_a2m() {
        let recs = parse(">a\nacDE.g-\n").unwrap();
        assert_eq!(recs[0].ungapped(), "ACDEG");
    }
}
