//! Multiple sequence alignments: loading, column statistics, subsampling.
//!
//! The canonical MSAs are generated at build time by `python/compile/data.py`
//! into `artifacts/msa/<family>.a2m` (first record = wild type); this module
//! also hosts a Rust-native simulator (`simulate`) used by tests and extra
//! workloads so the Rust side can run without artifacts.

pub mod fasta;
pub mod simulate;

use crate::tokenizer;
use crate::util::rng::Pcg64;
use std::path::Path;

/// An alignment: the wild-type row plus homolog rows (raw aligned strings).
#[derive(Clone, Debug)]
pub struct Msa {
    pub name: String,
    pub wild_type: String,
    /// Aligned homolog rows (may contain gaps).
    pub rows: Vec<String>,
}

#[derive(Debug)]
pub enum MsaError {
    Fasta(fasta::FastaError),
    NoRows(String),
}

impl std::fmt::Display for MsaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MsaError::Fasta(e) => write!(f, "{e}"),
            MsaError::NoRows(name) => write!(f, "msa {name} has no rows"),
        }
    }
}

impl std::error::Error for MsaError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MsaError::Fasta(e) => std::error::Error::source(e),
            MsaError::NoRows(_) => None,
        }
    }
}

impl From<fasta::FastaError> for MsaError {
    fn from(e: fasta::FastaError) -> MsaError {
        MsaError::Fasta(e)
    }
}

impl Msa {
    /// Load from an A2M file written by data.py (first record = wild type).
    pub fn load(path: &Path, name: &str) -> Result<Msa, MsaError> {
        let recs = fasta::read_path(path)?;
        if recs.len() < 2 {
            return Err(MsaError::NoRows(name.to_string()));
        }
        Ok(Msa {
            name: name.to_string(),
            wild_type: recs[0].ungapped(),
            rows: recs[1..].iter().map(|r| r.seq.clone()).collect(),
        })
    }

    pub fn depth(&self) -> usize {
        self.rows.len()
    }

    /// Alignment length (columns) of the first row.
    pub fn width(&self) -> usize {
        self.rows.first().map(|r| r.chars().count()).unwrap_or(0)
    }

    /// Deterministic subsample of `n` rows (Appendix C MSA-depth ablation).
    pub fn subsample(&self, n: usize, seed: u64) -> Msa {
        let mut rng = Pcg64::new(seed);
        let idx = rng.sample_indices(self.rows.len(), n);
        Msa {
            name: format!("{}@{}", self.name, n),
            wild_type: self.wild_type.clone(),
            rows: idx.iter().map(|&i| self.rows[i].clone()).collect(),
        }
    }

    /// Tokenized ungapped rows (no BOS/EOS).
    pub fn tokenized_rows(&self) -> Vec<Vec<u8>> {
        self.rows.iter().map(|r| tokenizer::encode(r)).collect()
    }

    /// Per-column residue frequency profile [width][20] ignoring gaps.
    pub fn column_profile(&self) -> Vec<[f64; tokenizer::N_AA]> {
        let w = self.width();
        let mut counts = vec![[0f64; tokenizer::N_AA]; w];
        for row in &self.rows {
            for (c, ch) in row.bytes().enumerate() {
                if c >= w {
                    break;
                }
                if let Some(t) = tokenizer::tok_of(ch) {
                    if tokenizer::is_residue(t) && t != tokenizer::X {
                        counts[c][(t - tokenizer::AA_OFFSET) as usize] += 1.0;
                    }
                }
            }
        }
        for col in counts.iter_mut() {
            let s: f64 = col.iter().sum();
            if s > 0.0 {
                col.iter_mut().for_each(|x| *x /= s);
            } else {
                col.iter_mut().for_each(|x| *x = 1.0 / tokenizer::N_AA as f64);
            }
        }
        counts
    }

    /// Per-column conservation: max residue frequency (1.0 = fully conserved).
    pub fn conservation(&self) -> Vec<f64> {
        self.column_profile()
            .iter()
            .map(|col| col.iter().cloned().fold(0.0, f64::max))
            .collect()
    }
}

/// Family metadata mirroring the paper's Table 1 (from families.json).
#[derive(Clone, Debug)]
pub struct FamilyMeta {
    pub name: String,
    pub paper_length: usize,
    pub length: usize,
    pub context: usize,
    pub paper_msa_depth: usize,
    pub msa_depth: usize,
    pub function: String,
    pub wild_type: String,
}

/// Parse families.json (written by data.py).
pub fn load_families(path: &Path) -> anyhow::Result<Vec<FamilyMeta>> {
    let text = std::fs::read_to_string(path)?;
    let v = crate::util::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("families.json: {e}"))?;
    let arr = v.as_arr().ok_or_else(|| anyhow::anyhow!("families.json: not an array"))?;
    let mut out = Vec::new();
    for f in arr {
        let s = |k: &str| -> anyhow::Result<String> {
            Ok(f.get(k)
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow::anyhow!("families.json missing {k}"))?
                .to_string())
        };
        let n = |k: &str| -> anyhow::Result<usize> {
            f.get(k)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow::anyhow!("families.json missing {k}"))
        };
        out.push(FamilyMeta {
            name: s("name")?,
            paper_length: n("paper_length")?,
            length: n("length")?,
            context: n("context")?,
            paper_msa_depth: n("paper_msa_depth")?,
            msa_depth: n("msa_depth")?,
            function: s("function")?,
            wild_type: s("wild_type")?,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_msa() -> Msa {
        Msa {
            name: "toy".into(),
            wild_type: "ACDE".into(),
            rows: vec!["ACDE".into(), "ACD-".into(), "AKDE".into(), "AC-E".into()],
        }
    }

    #[test]
    fn profile_and_conservation() {
        let m = toy_msa();
        let prof = m.column_profile();
        assert_eq!(prof.len(), 4);
        // column 0 is all A
        assert!((prof[0][0] - 1.0).abs() < 1e-12);
        let cons = m.conservation();
        assert_eq!(cons[0], 1.0);
        assert!(cons[1] < 1.0); // C,C,K,C
    }

    #[test]
    fn subsample_depth() {
        let m = toy_msa();
        let s = m.subsample(2, 1);
        assert_eq!(s.depth(), 2);
        assert_eq!(s.wild_type, m.wild_type);
        // deterministic
        let s2 = m.subsample(2, 1);
        assert_eq!(s.rows, s2.rows);
    }

    #[test]
    fn tokenized_rows_drop_gaps() {
        let m = toy_msa();
        let tok = m.tokenized_rows();
        assert_eq!(tok[1].len(), 3);
    }
}
