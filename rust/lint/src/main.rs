//! specmer-lint: repo-native static analysis for the SpecMER workspace.
//!
//! The correctness story of speculative decoding rests on contracts the Rust
//! compiler cannot see: verification is only lossless when draft and verify
//! kernels are bitwise-deterministic, `unsafe` kernel code is only sound under
//! invariants argued in prose, and the serving path must degrade to error
//! responses rather than panics. This binary scans `rust/src/**/*.rs` at the
//! token/line level (dependency-free — the offline build image has no registry
//! crates) and enforces six rules:
//!
//! 1. **unsafe-safety** — every `unsafe` block / fn / impl carries an adjacent
//!    `// SAFETY:` comment or a `# Safety` doc section.
//! 2. **nondeterminism** — kernel and decode modules (`runtime/`, `decode/`)
//!    may not use `Instant`, `SystemTime`, `RandomState`, `HashMap`, or
//!    `HashSet` (hash iteration order is randomized per-process) outside
//!    explicitly annotated metrics sites.
//! 3. **accumulation** — `runtime/gemm.rs` and `runtime/simd.rs` may not use
//!    f32 `.sum()` / `.fold(` / `.mul_add(` reductions, and FMA intrinsics
//!    are confined to `SPECMER_FAST`-gated paths (`if FMA { .. }` regions or
//!    functions whose name contains `fma`).
//! 4. **serving-panic** — no `unwrap` / `expect` / `panic!`-family macros on
//!    the serving request path (`server/`, `coordinator/`), excepting the
//!    lock-poisoning idiom (`.lock()` / `.wait()` / `.join()` receivers, which
//!    only fail once another thread has already panicked).
//! 5. **module-header** — every `src` module opens with a `//!` header.
//! 6. **unbounded** — no unbounded growth primitives on the serving path
//!    (`server/`, `coordinator/`): `VecDeque::new`, unbounded `channel()`
//!    construction, and `self.`-rooted `.push(` / `.push_back(` accumulators
//!    (state that outlives one call) must carry a
//!    `lint:allow(unbounded): <reason>` arguing the actual bound.
//!
//! Escape hatches (all require a non-empty justification, and a bare marker
//! is itself a violation):
//!
//! - `// lint:allow(<rule>): <reason>` on the offending line or the comment
//!   block directly above it.
//! - `// PANIC-OK: <reason>` for rule 4 specifically.
//!
//! `#[cfg(test)]` regions are skipped entirely. The policy this tool encodes
//! is written out in `docs/unsafe-policy.md`.
//!
//! Exit status: 0 when the tree is clean, 1 with one line per violation
//! otherwise. Run via `make lint-specmer` or `cargo run -p specmer-lint`.

use std::fmt;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------------------
// Violations
// ---------------------------------------------------------------------------

/// A single rule violation, addressed by path relative to `rust/src`.
#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "rust/src/{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.msg
        )
    }
}

// ---------------------------------------------------------------------------
// Lexical stripping
// ---------------------------------------------------------------------------

/// Per-line view of a source file after lexical stripping: `code` holds the
/// source with comments and string/char literal *contents* blanked out (so
/// brace counting and token matching never trip over literal text), `com`
/// holds the comment text of each line, and `test` marks lines inside
/// `#[cfg(test)]` items.
struct FileView {
    code: Vec<String>,
    com: Vec<String>,
    test: Vec<bool>,
}

/// Split source into parallel per-line code / comment streams.
///
/// Handles line comments, nested block comments, string literals with escape
/// sequences (including the `\<newline>` continuation), raw strings
/// (`r"…"`, `r#"…"#`, with optional `b` prefix), byte strings, char literals,
/// and lifetimes (`'a` is not a char literal).
fn strip(src: &str) -> (Vec<String>, Vec<String>) {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
        CharLit,
    }
    let cs: Vec<char> = src.chars().collect();
    let mut code_lines = Vec::new();
    let mut com_lines = Vec::new();
    let mut code = String::new();
    let mut com = String::new();
    let mut st = St::Code;
    let mut i = 0;
    while i < cs.len() {
        let c = cs[i];
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            code_lines.push(std::mem::take(&mut code));
            com_lines.push(std::mem::take(&mut com));
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let next = cs.get(i + 1).copied();
                let prev_ident = i > 0 && is_ident(cs[i - 1]);
                if c == '/' && next == Some('/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    code.push(' ');
                    st = St::Str;
                    i += 1;
                } else if c == '\'' {
                    // Char literal (`'x'`, `'\n'`) vs. lifetime (`'a`).
                    let is_char = match next {
                        Some('\\') => true,
                        Some(n) => n != '\'' && cs.get(i + 2) == Some(&'\''),
                        None => false,
                    };
                    code.push(' ');
                    if is_char {
                        st = St::CharLit;
                    }
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_ident {
                    // Possible raw / byte string: r"…", r#"…"#, br"…", b"…",
                    // or byte char b'…'.
                    let mut j = i;
                    if cs[j] == 'b' {
                        j += 1;
                    }
                    let mut hashes = 0u32;
                    if cs.get(j) == Some(&'r') {
                        j += 1;
                        while cs.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                        if cs.get(j) == Some(&'"') {
                            code.push(' ');
                            st = St::RawStr(hashes);
                            i = j + 1;
                            continue;
                        }
                    } else if c == 'b' && cs.get(j) == Some(&'"') {
                        code.push(' ');
                        st = St::Str;
                        i = j + 1;
                        continue;
                    } else if c == 'b' && cs.get(j) == Some(&'\'') {
                        code.push(' ');
                        st = St::CharLit;
                        i = j + 1;
                        continue;
                    }
                    code.push(c);
                    i += 1;
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                com.push(c);
                i += 1;
            }
            St::BlockComment(d) => {
                let next = cs.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    st = St::BlockComment(d + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    st = if d == 1 { St::Code } else { St::BlockComment(d - 1) };
                    i += 2;
                } else {
                    com.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    // Skip the escaped char, but never swallow a newline:
                    // `\<newline>` is a line continuation and the outer loop
                    // must still see the `\n` to keep line numbers aligned.
                    if cs.get(i + 1).is_some_and(|&n| n != '\n') {
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == '"' {
                    code.push(' ');
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' && (1..=h as usize).all(|k| cs.get(i + k) == Some(&'#')) {
                    code.push(' ');
                    st = St::Code;
                    i += 1 + h as usize;
                } else {
                    i += 1;
                }
            }
            St::CharLit => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !src.is_empty() && !src.ends_with('\n') {
        code_lines.push(code);
        com_lines.push(com);
    }
    (code_lines, com_lines)
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Mark every line that belongs to a `#[cfg(test)]` item (module, fn, or a
/// braceless item like `use`). Brace depth is tracked over the blanked code
/// stream so literal braces cannot desynchronize it.
fn mark_tests(code: &[String]) -> Vec<bool> {
    let mut test = vec![false; code.len()];
    let mut depth: i64 = 0;
    let mut armed = false;
    let mut region_base: Option<i64> = None;
    for (ln, line) in code.iter().enumerate() {
        if line.contains("#[cfg(test)]") {
            armed = true;
        }
        let mut hit = armed || region_base.is_some();
        for ch in line.chars() {
            match ch {
                '{' => {
                    if armed && region_base.is_none() {
                        region_base = Some(depth);
                        armed = false;
                        hit = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if region_base.is_some_and(|b| depth <= b) {
                        region_base = None;
                    }
                }
                ';' => {
                    // `#[cfg(test)]` on a braceless item ends at its `;`.
                    if armed && region_base.is_none() {
                        armed = false;
                    }
                }
                _ => {}
            }
            if region_base.is_some() {
                hit = true;
            }
        }
        test[ln] = hit;
    }
    test
}

fn view(src: &str) -> FileView {
    let (code, com) = strip(src);
    let test = mark_tests(&code);
    FileView { code, com, test }
}

// ---------------------------------------------------------------------------
// Shared matching helpers
// ---------------------------------------------------------------------------

/// True when `w` occurs in `s` as a standalone word (no identifier characters
/// on either side).
fn has_word(s: &str, w: &str) -> bool {
    let mut start = 0;
    while let Some(p) = s[start..].find(w) {
        let a = start + p;
        let z = a + w.len();
        let pre = a == 0 || !is_ident(s.as_bytes()[a - 1] as char);
        let post = z >= s.len() || !is_ident(s.as_bytes()[z] as char);
        if pre && post {
            return true;
        }
        start = a + 1;
    }
    false
}

/// Comment text adjacent above line `ln`: the run of pure-comment, attribute,
/// or doc lines directly preceding it, newest-last. A blank line or a line of
/// real code terminates the run — adjacency is the point.
fn leading_comment(v: &FileView, ln: usize) -> String {
    let mut parts: Vec<&str> = Vec::new();
    let mut i = ln;
    while i > 0 {
        i -= 1;
        let code = v.code[i].trim();
        let com = v.com[i].trim();
        let attr_only = code.starts_with("#[") || code.starts_with("#![");
        if code.is_empty() && com.is_empty() {
            break;
        }
        if code.is_empty() || attr_only {
            parts.push(com);
        } else {
            break;
        }
    }
    parts.reverse();
    parts.join("\n")
}

/// Look for `lint:allow(<rule>): reason` on the line itself or its adjacent
/// comment block. Returns `None` when absent, `Some(true)` when present with
/// a justification, `Some(false)` for a bare marker.
fn allow_marker(v: &FileView, ln: usize, rule: &str) -> Option<bool> {
    let pat = format!("lint:allow({rule})");
    marker_with_reason(v, ln, &pat)
}

fn marker_with_reason(v: &FileView, ln: usize, pat: &str) -> Option<bool> {
    let above = leading_comment(v, ln);
    for text in [v.com[ln].as_str(), above.as_str()] {
        if let Some(p) = text.find(pat) {
            let rest = &text[p + pat.len()..];
            let reason = rest
                .trim_start()
                .strip_prefix(':')
                .map(|r| r.lines().next().unwrap_or("").trim())
                .unwrap_or("");
            return Some(!reason.is_empty());
        }
    }
    None
}

/// Apply an allow-marker to a candidate violation: marker with reason
/// suppresses it, a bare marker converts it into a marker-hygiene violation.
fn apply_marker(
    v: &FileView,
    ln: usize,
    rule: &'static str,
    file: &str,
    msg: String,
    out: &mut Vec<Violation>,
) {
    match allow_marker(v, ln, rule) {
        Some(true) => {}
        Some(false) => out.push(Violation {
            file: file.into(),
            line: ln + 1,
            rule,
            msg: format!("bare `lint:allow({rule})` marker requires a justification"),
        }),
        None => out.push(Violation { file: file.into(), line: ln + 1, rule, msg }),
    }
}

// ---------------------------------------------------------------------------
// Rule 1: unsafe sites need adjacent SAFETY comments
// ---------------------------------------------------------------------------

fn rule_unsafe_safety(file: &str, v: &FileView, out: &mut Vec<Violation>) {
    for ln in 0..v.code.len() {
        if v.test[ln] || !has_word(&v.code[ln], "unsafe") {
            continue;
        }
        let near = leading_comment(v, ln);
        let ok = v.com[ln].contains("SAFETY:")
            || near.contains("SAFETY:")
            || near.contains("# Safety");
        if !ok {
            out.push(Violation {
                file: file.into(),
                line: ln + 1,
                rule: "unsafe-safety",
                msg: "`unsafe` without an adjacent `// SAFETY:` comment or `# Safety` doc \
                      section"
                    .into(),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Rule 2: no nondeterminism in kernel / decode modules
// ---------------------------------------------------------------------------

const NONDET_TOKENS: [&str; 5] = ["Instant", "SystemTime", "RandomState", "HashMap", "HashSet"];

fn rule_nondeterminism(file: &str, v: &FileView, out: &mut Vec<Violation>) {
    for ln in 0..v.code.len() {
        if v.test[ln] {
            continue;
        }
        for tok in NONDET_TOKENS {
            if has_word(&v.code[ln], tok) {
                apply_marker(
                    v,
                    ln,
                    "nondeterminism",
                    file,
                    format!(
                        "`{tok}` in a kernel/decode module breaks bitwise reproducibility \
                         (wall clocks and randomized hash iteration order are \
                         nondeterministic); use BTreeMap/BTreeSet or annotate a metrics \
                         site with `lint:allow(nondeterminism): <reason>`"
                    ),
                    out,
                );
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Rule 3: bitwise-accumulation contract in runtime::{gemm, simd}
// ---------------------------------------------------------------------------

fn rule_accumulation(file: &str, v: &FileView, out: &mut Vec<Violation>) {
    // Track which lines sit inside an `if FMA { … }` region or a function
    // whose name contains "fma" — the SPECMER_FAST-gated paths where fused
    // multiply-add is part of the contract rather than a violation of it.
    let mut depth: i64 = 0;
    let mut pending_fn_fma: Option<bool> = None;
    let mut pending_if_fma = false;
    let mut fn_regions: Vec<(i64, bool)> = Vec::new();
    let mut if_regions: Vec<i64> = Vec::new();
    for ln in 0..v.code.len() {
        let line = &v.code[ln];
        if let Some(name) = fn_name(line) {
            pending_fn_fma = Some(name.contains("fma"));
        }
        if has_word(line, "if") && has_word(line, "FMA") {
            pending_if_fma = true;
        }
        let fma_ok_at_entry = !if_regions.is_empty()
            || fn_regions.iter().any(|&(_, f)| f)
            || pending_fn_fma == Some(true)
            || pending_if_fma;
        for ch in line.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if pending_if_fma {
                        if_regions.push(depth);
                        pending_if_fma = false;
                    } else if let Some(f) = pending_fn_fma.take() {
                        fn_regions.push((depth, f));
                    }
                }
                '}' => {
                    if if_regions.last() == Some(&depth) {
                        if_regions.pop();
                    }
                    if fn_regions.last().map(|&(d, _)| d) == Some(depth) {
                        fn_regions.pop();
                    }
                    depth -= 1;
                }
                ';' => {
                    // A braceless `fn` declaration (trait method) or a
                    // statement boundary: any pending markers are dead.
                    pending_fn_fma = None;
                    pending_if_fma = false;
                }
                _ => {}
            }
        }
        if v.test[ln] {
            continue;
        }
        for tok in [".sum()", ".fold(", ".mul_add("] {
            if line.contains(tok) {
                apply_marker(
                    v,
                    ln,
                    "accumulation",
                    file,
                    format!(
                        "`{tok}` in a bitwise-deterministic kernel module: reductions must \
                         be explicit serial loops in fixed k-order (see \
                         docs/unsafe-policy.md)"
                    ),
                    out,
                );
            }
        }
        if line.contains("fmadd") && !fma_ok_at_entry {
            apply_marker(
                v,
                ln,
                "accumulation",
                file,
                "FMA intrinsic outside a SPECMER_FAST-gated path (`if FMA { .. }` or a \
                 `*fma*`-named function): fused rounding diverges from the scalar \
                 reference"
                    .into(),
                out,
            );
        }
    }
}

/// Extract the identifier after `fn ` on a declaration line, if any.
fn fn_name(line: &str) -> Option<&str> {
    let p = line.find("fn ")?;
    // Require a token boundary before `fn` (skip e.g. `pub fn`, reject idents
    // like `my_fn `).
    if p > 0 && is_ident(line.as_bytes()[p - 1] as char) {
        return None;
    }
    let rest = line[p + 3..].trim_start();
    let end = rest.find(|c: char| !is_ident(c)).unwrap_or(rest.len());
    if end == 0 {
        None
    } else {
        Some(&rest[..end])
    }
}

// ---------------------------------------------------------------------------
// Rule 4: no panics on the serving request path
// ---------------------------------------------------------------------------

const PANIC_TOKENS: [&str; 6] =
    [".unwrap()", ".expect(", "panic!", "unreachable!", "todo!", "unimplemented!"];

/// Receivers whose failure already implies a panic elsewhere (poisoned lock /
/// condvar, or joining a panicked thread): unwrapping them only propagates an
/// existing panic, which is the documented idiom in this repo.
const LOCK_IDIOM: [&str; 4] = [".lock(", ".wait(", ".wait_timeout(", ".join("];

fn rule_serving_panic(file: &str, v: &FileView, out: &mut Vec<Violation>) {
    for ln in 0..v.code.len() {
        if v.test[ln] {
            continue;
        }
        let line = &v.code[ln];
        let hit = PANIC_TOKENS.iter().find(|t| line.contains(*t));
        let Some(tok) = hit else { continue };
        // Lock-poisoning idiom: the receiver is on the same line or — for
        // split method chains — the nearest preceding code line.
        let prev = (0..ln)
            .rev()
            .map(|j| v.code[j].trim())
            .find(|l| !l.is_empty())
            .unwrap_or("");
        if LOCK_IDIOM.iter().any(|p| line.contains(p) || prev.contains(p)) {
            continue;
        }
        match marker_with_reason(v, ln, "PANIC-OK") {
            Some(true) => {}
            Some(false) => out.push(Violation {
                file: file.into(),
                line: ln + 1,
                rule: "serving-panic",
                msg: "bare `PANIC-OK` marker requires a justification".into(),
            }),
            None => out.push(Violation {
                file: file.into(),
                line: ln + 1,
                rule: "serving-panic",
                msg: format!(
                    "`{tok}` on the serving request path: convert to an error response \
                     (anyhow::Result) or annotate with `// PANIC-OK: <reason>`"
                ),
            }),
        }
    }
}

// ---------------------------------------------------------------------------
// Rule 6: no unbounded growth primitives on the serving path
// ---------------------------------------------------------------------------

/// Append-style calls that grow a collection by one element.
const GROW_CALLS: [&str; 3] = [".push(", ".push_back(", ".push_front("];

/// True when `line` constructs an unbounded mpsc channel: the word
/// `channel` immediately followed by `(`. `sync_channel(` (bounded) has an
/// identifier character before the match and never fires.
fn channel_call(line: &str) -> bool {
    let mut start = 0;
    while let Some(p) = line[start..].find("channel(") {
        let a = start + p;
        if a == 0 || !is_ident(line.as_bytes()[a - 1] as char) {
            return true;
        }
        start = a + 1;
    }
    false
}

/// Whether the method chain ending at byte offset `dot` (the `.` of a
/// `.push(`-style call) is rooted at `self` — i.e. grows state that
/// outlives the enclosing call, rather than a local accumulator.
fn chain_rooted_at_self(line: &str, dot: usize) -> bool {
    let bytes = line.as_bytes();
    let mut i = dot;
    while i > 0 {
        let c = bytes[i - 1] as char;
        if is_ident(c) || matches!(c, '.' | '(' | ')' | '[' | ']') {
            i -= 1;
        } else {
            break;
        }
    }
    line[i..dot].starts_with("self.")
}

fn rule_unbounded(file: &str, v: &FileView, out: &mut Vec<Violation>) {
    for ln in 0..v.code.len() {
        if v.test[ln] {
            continue;
        }
        let line = &v.code[ln];
        if line.contains("VecDeque::new") {
            apply_marker(
                v,
                ln,
                "unbounded",
                file,
                "`VecDeque::new` on the serving path has no capacity bound: overload must \
                 shed, not grow memory; enforce a bound and annotate it with \
                 `lint:allow(unbounded): <reason>`"
                    .into(),
                out,
            );
            continue;
        }
        if channel_call(line) {
            apply_marker(
                v,
                ln,
                "unbounded",
                file,
                "unbounded `channel()` on the serving path: senders can outrun the \
                 receiver without backpressure; bound the producers and annotate with \
                 `lint:allow(unbounded): <reason>`"
                    .into(),
                out,
            );
            continue;
        }
        for tok in GROW_CALLS {
            let mut start = 0;
            let mut hit = false;
            while let Some(p) = line[start..].find(tok) {
                let dot = start + p;
                if chain_rooted_at_self(line, dot) {
                    hit = true;
                    break;
                }
                start = dot + 1;
            }
            if hit {
                apply_marker(
                    v,
                    ln,
                    "unbounded",
                    file,
                    format!(
                        "`{tok}` onto `self.`-rooted state on the serving path is an \
                         accumulator that outlives this call: argue its bound with \
                         `lint:allow(unbounded): <reason>`"
                    ),
                    out,
                );
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Rule 5: module headers
// ---------------------------------------------------------------------------

fn rule_module_header(file: &str, src: &str, out: &mut Vec<Violation>) {
    let first = src.lines().find(|l| !l.trim().is_empty());
    let ok = first.is_some_and(|l| l.trim_start().starts_with("//!"));
    if !ok {
        out.push(Violation {
            file: file.into(),
            line: 1,
            rule: "module-header",
            msg: "module must open with a `//!` header documenting its role".into(),
        });
    }
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

/// Scan one file's source. `rel` is the path relative to `rust/src`, with
/// forward slashes; it selects which rules apply.
fn scan_source(rel: &str, src: &str) -> Vec<Violation> {
    let v = view(src);
    let mut out = Vec::new();
    rule_module_header(rel, src, &mut out);
    rule_unsafe_safety(rel, &v, &mut out);
    if rel.starts_with("runtime/") || rel.starts_with("decode/") {
        rule_nondeterminism(rel, &v, &mut out);
    }
    if rel == "runtime/gemm.rs" || rel == "runtime/simd.rs" {
        rule_accumulation(rel, &v, &mut out);
    }
    if rel.starts_with("server/") || rel.starts_with("coordinator/") {
        rule_serving_panic(rel, &v, &mut out);
        rule_unbounded(rel, &v, &mut out);
    }
    out
}

fn walk(dir: &Path, files: &mut Vec<PathBuf>) {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("specmer-lint: cannot read {}: {e}", dir.display());
            std::process::exit(2);
        }
    };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            walk(&p, files);
        } else if p.extension().is_some_and(|e| e == "rs") {
            files.push(p);
        }
    }
}

fn main() {
    // The lint crate lives at <repo>/rust/lint, so the tree under scan is
    // two levels up from the manifest.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .and_then(Path::parent)
        .expect("specmer-lint must live at <repo>/rust/lint");
    let src = root.join("rust").join("src");
    let mut files = Vec::new();
    walk(&src, &mut files);
    files.sort();
    let mut violations = Vec::new();
    for f in &files {
        let rel = f
            .strip_prefix(&src)
            .expect("walked file is under rust/src")
            .to_string_lossy()
            .replace('\\', "/");
        let text = match std::fs::read_to_string(f) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("specmer-lint: cannot read {}: {e}", f.display());
                std::process::exit(2);
            }
        };
        violations.extend(scan_source(&rel, &text));
    }
    if violations.is_empty() {
        println!("specmer-lint: {} files clean", files.len());
    } else {
        for v in &violations {
            eprintln!("{v}");
        }
        eprintln!("specmer-lint: {} violation(s)", violations.len());
        std::process::exit(1);
    }
}

// ---------------------------------------------------------------------------
// Fixture tests: each rule must fire on a violating snippet and stay quiet on
// a conforming one.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(rel: &str, src: &str) -> Vec<&'static str> {
        scan_source(rel, src).into_iter().map(|v| v.rule).collect()
    }

    // -- lexer ------------------------------------------------------------

    #[test]
    fn strip_blanks_strings_and_comments() {
        let (code, com) = strip("let s = \"un{safe}\"; // unsafe note\nlet t = 'x';\n");
        assert!(!code[0].contains("un{safe}"), "string contents must be blanked");
        assert!(code[0].contains("let s ="));
        assert!(com[0].contains("unsafe note"));
        assert!(!code[1].contains('x') || code[1].contains("let t"));
    }

    #[test]
    fn strip_handles_lifetimes_and_raw_strings() {
        let (code, _) = strip("fn f<'a>(x: &'a str) { let r = r#\"{ } \"#; }\n");
        // Lifetimes must not open a char literal and raw-string braces must
        // not leak into the code stream.
        let braces = code[0].matches('{').count();
        assert_eq!(braces, 1, "only the fn body brace survives: {:?}", code[0]);
    }

    #[test]
    fn strip_handles_block_comments_and_escapes() {
        let (code, com) = strip("a /* b { */ c\nlet q = \"\\\"{\"; d\n");
        assert!(code[0].contains('a') && code[0].contains('c') && !code[0].contains('{'));
        assert!(com[0].contains('b'));
        assert!(!code[1].contains('{'), "escaped quote must not end the string early");
        assert!(code[1].contains('d'));
    }

    #[test]
    fn test_regions_are_marked() {
        let src = "//! m\nfn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap() }\n}\nfn live2() {}\n";
        let v = view(src);
        assert!(!v.test[1]);
        assert!(v.test[2] && v.test[3] && v.test[4] && v.test[5]);
        assert!(!v.test[6]);
    }

    // -- rule 1 -----------------------------------------------------------

    #[test]
    fn unsafe_without_safety_fires() {
        let src = "//! m\nfn f(p: *const f32) -> f32 {\n    unsafe { *p }\n}\n";
        assert!(rules_hit("runtime/x.rs", src).contains(&"unsafe-safety"));
    }

    #[test]
    fn unsafe_with_adjacent_safety_passes() {
        let src = "//! m\nfn f(p: *const f32) -> f32 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n";
        assert!(!rules_hit("runtime/x.rs", src).contains(&"unsafe-safety"));
    }

    #[test]
    fn unsafe_fn_with_safety_doc_section_passes() {
        let src = "//! m\n/// Does things.\n///\n/// # Safety\n/// `p` must be valid.\npub unsafe fn f(p: *const f32) -> f32 {\n    // SAFETY: p valid per contract.\n    unsafe { *p }\n}\n";
        assert!(!rules_hit("runtime/x.rs", src).contains(&"unsafe-safety"));
    }

    #[test]
    fn unsafe_in_test_region_is_skipped() {
        let src = "//! m\n#[cfg(test)]\nmod tests {\n    fn f(p: *const f32) -> f32 {\n        unsafe { *p }\n    }\n}\n";
        assert!(!rules_hit("runtime/x.rs", src).contains(&"unsafe-safety"));
    }

    #[test]
    fn unsafe_inside_string_is_ignored() {
        let src = "//! m\nfn f() -> &'static str {\n    \"unsafe\"\n}\n";
        assert!(!rules_hit("runtime/x.rs", src).contains(&"unsafe-safety"));
    }

    // -- rule 2 -----------------------------------------------------------

    #[test]
    fn hashmap_in_runtime_fires() {
        let src = "//! m\nuse std::collections::HashMap;\n";
        assert!(rules_hit("runtime/x.rs", src).contains(&"nondeterminism"));
    }

    #[test]
    fn instant_with_reasoned_allow_passes() {
        let src = "//! m\n// lint:allow(nondeterminism): compile-timing metrics site\nuse std::time::Instant;\n";
        assert!(!rules_hit("runtime/x.rs", src).contains(&"nondeterminism"));
    }

    #[test]
    fn bare_allow_marker_fires() {
        let src = "//! m\n// lint:allow(nondeterminism)\nuse std::time::Instant;\n";
        let v = scan_source("runtime/x.rs", src);
        assert!(v.iter().any(|v| v.rule == "nondeterminism" && v.msg.contains("justification")));
    }

    #[test]
    fn hashmap_outside_scope_passes() {
        let src = "//! m\nuse std::collections::HashMap;\n";
        assert!(!rules_hit("util/x.rs", src).contains(&"nondeterminism"));
    }

    // -- rule 3 -----------------------------------------------------------

    #[test]
    fn sum_in_gemm_fires() {
        let src = "//! m\nfn f(x: &[f32]) -> f32 {\n    x.iter().sum()\n}\n";
        assert!(rules_hit("runtime/gemm.rs", src).contains(&"accumulation"));
    }

    #[test]
    fn fmadd_outside_gate_fires() {
        let src = "//! m\nunsafe fn f() {\n    // SAFETY: test fixture.\n    let acc = _mm256_fmadd_ps(a, b, c);\n}\n";
        assert!(rules_hit("runtime/gemm.rs", src).contains(&"accumulation"));
    }

    #[test]
    fn fmadd_inside_if_fma_region_passes() {
        let src = "//! m\nfn f() {\n    const FMA: bool = true;\n    if FMA {\n        let acc = _mm256_fmadd_ps(a, b, c);\n    } else {\n        let acc = add(mul(a, b), c);\n    }\n}\n";
        assert!(!rules_hit("runtime/gemm.rs", src).contains(&"accumulation"));
    }

    #[test]
    fn fmadd_after_if_fma_region_fires() {
        let src = "//! m\nfn f() {\n    if FMA {\n        let acc = _mm256_fmadd_ps(a, b, c);\n    }\n    let bad = _mm256_fmadd_ps(a, b, c);\n}\n";
        assert!(rules_hit("runtime/gemm.rs", src).contains(&"accumulation"));
    }

    #[test]
    fn fmadd_in_fma_named_fn_passes() {
        let src = "//! m\npub unsafe fn rows_f32_fma() {\n    // SAFETY: test fixture.\n    let acc = _mm256_fmadd_ps(a, b, c);\n}\n";
        assert!(!rules_hit("runtime/gemm.rs", src).contains(&"accumulation"));
    }

    #[test]
    fn sum_outside_kernel_modules_passes() {
        let src = "//! m\nfn f(x: &[f32]) -> f32 {\n    x.iter().sum()\n}\n";
        assert!(!rules_hit("runtime/cpu_ref.rs", src).contains(&"accumulation"));
    }

    // -- rule 4 -----------------------------------------------------------

    #[test]
    fn unwrap_on_request_path_fires() {
        let src = "//! m\nfn handle(r: Request) -> u32 {\n    r.field.unwrap()\n}\n";
        assert!(rules_hit("server/x.rs", src).contains(&"serving-panic"));
        assert!(rules_hit("coordinator/x.rs", src).contains(&"serving-panic"));
    }

    #[test]
    fn lock_idiom_same_line_passes() {
        let src = "//! m\nfn f(m: &Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n";
        assert!(!rules_hit("coordinator/x.rs", src).contains(&"serving-panic"));
    }

    #[test]
    fn lock_idiom_split_chain_passes() {
        let src = "//! m\nfn f(m: &Mutex<u32>) -> u32 {\n    *m\n        .lock()\n        .unwrap()\n}\n";
        assert!(!rules_hit("coordinator/x.rs", src).contains(&"serving-panic"));
    }

    #[test]
    fn panic_ok_with_reason_passes() {
        let src = "//! m\nfn boot() {\n    // PANIC-OK: thread spawn failure at startup is fatal by design.\n    spawn().expect(\"spawn worker\");\n}\n";
        assert!(!rules_hit("coordinator/x.rs", src).contains(&"serving-panic"));
    }

    #[test]
    fn bare_panic_ok_fires() {
        let src = "//! m\nfn boot() {\n    // PANIC-OK\n    spawn().expect(\"spawn worker\");\n}\n";
        let v = scan_source("coordinator/x.rs", src);
        assert!(v.iter().any(|v| v.rule == "serving-panic" && v.msg.contains("justification")));
    }

    #[test]
    fn unwrap_or_is_not_unwrap() {
        let src = "//! m\nfn f(x: Option<u32>) -> u32 {\n    x.unwrap_or(0)\n}\n";
        assert!(!rules_hit("server/x.rs", src).contains(&"serving-panic"));
    }

    #[test]
    fn unwrap_in_tests_passes() {
        let src = "//! m\n#[cfg(test)]\nmod tests {\n    fn t(x: Option<u32>) -> u32 {\n        x.unwrap()\n    }\n}\n";
        assert!(!rules_hit("server/x.rs", src).contains(&"serving-panic"));
    }

    // -- rule 6 -----------------------------------------------------------

    #[test]
    fn vecdeque_new_on_serving_path_fires() {
        let src = "//! m\nfn f() -> VecDeque<u32> {\n    VecDeque::new()\n}\n";
        assert!(rules_hit("coordinator/x.rs", src).contains(&"unbounded"));
        assert!(rules_hit("server/x.rs", src).contains(&"unbounded"));
    }

    #[test]
    fn vecdeque_with_reasoned_allow_passes() {
        let src = "//! m\nfn f() -> VecDeque<u32> {\n    // lint:allow(unbounded): capacity enforced in try_push\n    VecDeque::new()\n}\n";
        assert!(!rules_hit("coordinator/x.rs", src).contains(&"unbounded"));
    }

    #[test]
    fn unbounded_channel_fires_but_sync_channel_passes() {
        let src = "//! m\nfn f() {\n    let (tx, rx) = channel();\n}\n";
        assert!(rules_hit("server/x.rs", src).contains(&"unbounded"));
        let src = "//! m\nfn f() {\n    let (tx, rx) = mpsc::channel();\n}\n";
        assert!(rules_hit("server/x.rs", src).contains(&"unbounded"));
        let src = "//! m\nfn f() {\n    let (tx, rx) = sync_channel(8);\n}\n";
        assert!(!rules_hit("server/x.rs", src).contains(&"unbounded"));
    }

    #[test]
    fn self_rooted_push_fires_but_local_push_passes() {
        let src = "//! m\nimpl S {\n    fn f(&mut self, v: u32) {\n        self.items.push(v);\n    }\n}\n";
        assert!(rules_hit("coordinator/x.rs", src).contains(&"unbounded"));
        // chained self receiver still fires
        let src = "//! m\nimpl S {\n    fn f(&mut self, v: f64) {\n        self.latencies.lock().unwrap().push(v);\n    }\n}\n";
        assert!(rules_hit("coordinator/x.rs", src).contains(&"unbounded"));
        // a local accumulator fed *from* self is not an accumulator on self
        let src = "//! m\nimpl S {\n    fn f(&mut self) {\n        let mut batch = Vec::new();\n        batch.push(self.queue.pop_front());\n    }\n}\n";
        assert!(!rules_hit("coordinator/x.rs", src).contains(&"unbounded"));
    }

    #[test]
    fn bare_unbounded_marker_fires() {
        let src = "//! m\nfn f() {\n    // lint:allow(unbounded)\n    let (tx, rx) = channel();\n}\n";
        let v = scan_source("server/x.rs", src);
        assert!(v.iter().any(|v| v.rule == "unbounded" && v.msg.contains("justification")));
    }

    #[test]
    fn unbounded_outside_serving_path_passes() {
        let src = "//! m\nfn f() -> VecDeque<u32> {\n    VecDeque::new()\n}\n";
        assert!(!rules_hit("util/x.rs", src).contains(&"unbounded"));
        let src = "//! m\nimpl S {\n    fn f(&mut self, v: u32) {\n        self.items.push(v);\n    }\n}\n";
        assert!(!rules_hit("decode/x.rs", src).contains(&"unbounded"));
    }

    #[test]
    fn unbounded_in_test_region_passes() {
        let src = "//! m\n#[cfg(test)]\nmod tests {\n    fn t() {\n        let (tx, rx) = channel();\n    }\n}\n";
        assert!(!rules_hit("coordinator/x.rs", src).contains(&"unbounded"));
    }

    // -- rule 5 -----------------------------------------------------------

    #[test]
    fn missing_module_header_fires() {
        assert!(rules_hit("util/x.rs", "fn f() {}\n").contains(&"module-header"));
    }

    #[test]
    fn module_header_passes() {
        assert!(!rules_hit("util/x.rs", "//! A module.\nfn f() {}\n").contains(&"module-header"));
    }

    // -- the real tree ----------------------------------------------------

    #[test]
    fn repo_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .and_then(Path::parent)
            .expect("lint crate location");
        let src = root.join("rust").join("src");
        let mut files = Vec::new();
        walk(&src, &mut files);
        files.sort();
        assert!(!files.is_empty(), "expected sources under {}", src.display());
        let mut bad = Vec::new();
        for f in &files {
            let rel =
                f.strip_prefix(&src).unwrap().to_string_lossy().replace('\\', "/");
            let text = std::fs::read_to_string(f).unwrap();
            bad.extend(scan_source(&rel, &text));
        }
        assert!(
            bad.is_empty(),
            "repo tree has lint violations:\n{}",
            bad.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
