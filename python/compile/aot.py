"""AOT artifact builder: data -> train -> HLO text + params + manifest.

This is the ONLY place Python runs; everything it emits under artifacts/
is consumed by the Rust serving binary.  Interchange is HLO *text* (not
serialized HloModuleProto): jax >= 0.5 emits 64-bit instruction ids that
xla_extension 0.5.1 rejects, while the text parser reassigns ids (see
/opt/xla-example/README.md).

Idempotence: a content stamp over the compile-path sources + config makes
`make artifacts` a no-op when nothing changed.  `--fast` trains tiny
checkpoints (CI/smoke); `--stage` allows partial rebuilds.

Exported programs (see DESIGN.md §2 for the full table):
  {m}_prefill                       m in {draft, target, xl}
  {m}_generate_c{C}_g{G}            draft: C in C_LIST, G in G_LIST;
                                    target/xl: C=1 only (AR baseline chunks)
  {m}_verify_g{G}                   target, xl
  target_score, target_embed, draft_score
  kmer_score_c8_g{G}                Pallas k-mer scorer
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, train, vocab
from .kernels.kmer_score import HSZ, V as KV
from .model import CONFIGS, DRAFT, MAXLEN, TARGET, XL, ModelCfg
from . import model as M

C_LIST = [1, 2, 3, 5, 8]
G_LIST = [5, 10, 15]
AR_CHUNK = 16  # target-only baseline generates in chunks of this many tokens

HERE = os.path.dirname(os.path.abspath(__file__))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(fn, args, path):
    # keep_unused: the Rust side passes every declared argument (e.g.
    # prefill's n_ctx, which exists for interface clarity only) — without
    # this, XLA drops unused params and arity no longer matches.
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def f32():
    return jnp.float32


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def content_stamp(cfg_note: str) -> str:
    h = hashlib.sha256()
    for fn in ("vocab.py", "data.py", "model.py", "train.py", "aot.py",
               "kernels/attention.py", "kernels/kmer_score.py", "kernels/ref.py"):
        with open(os.path.join(HERE, fn), "rb") as f:
            h.update(f.read())
    h.update(cfg_note.encode())
    return h.hexdigest()[:16]


def build_data(out: str):
    print("[aot] generating family MSAs")
    return data.build_all(out)


def build_models(out: str, fast: bool):
    steps_t, steps_d, steps_x = (60, 40, 40) if fast else (1200, 800, 300)
    tr, hold = data.training_corpus(out)
    print(f"[aot] corpus: {len(tr)} train / {len(hold)} holdout sequences")
    params = {}
    print("[aot] training target", TARGET.n_params(), "params")
    params["target"] = train.train_model(TARGET, tr, hold, steps=steps_t, seed=7)
    print("[aot] training draft (distilled)", DRAFT.n_params(), "params")
    params["draft"] = train.train_model(
        DRAFT, tr, hold, steps=steps_d, seed=8,
        teacher=(TARGET, jnp.asarray(params["target"])))
    print("[aot] training xl", XL.n_params(), "params")
    params["xl"] = train.train_model(XL, tr, hold, steps=steps_x, seed=9)

    manifest = {"maxlen": MAXLEN, "vocab": vocab.VOCAB, "models": {}}
    for name, flat in params.items():
        cfg = CONFIGS[name]
        flat.tofile(os.path.join(out, f"params_{name}.bin"))
        offs, off = [], 0
        for pname, shape in cfg.param_specs():
            n = int(np.prod(shape))
            offs.append({"name": pname, "shape": list(shape), "offset": off})
            off += n
        manifest["models"][name] = {
            "n_layer": cfg.n_layer, "d_model": cfg.d_model,
            "n_head": cfg.n_head, "d_ff": cfg.d_ff,
            "n_params": cfg.n_params(), "tensors": offs,
            "cache_shape": list(cfg.cache_shape()),
        }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return params


def export_programs(out: str, use_pallas: bool = True):
    os.makedirs(os.path.join(out, "hlo"), exist_ok=True)
    total = 0

    def ex(name, fn, args):
        nonlocal total
        t0 = time.time()
        n = export(fn, args, os.path.join(out, "hlo", f"{name}.hlo.txt"))
        total += n
        print(f"  hlo {name}: {n//1024} KiB ({time.time()-t0:.1f}s)")

    i32 = jnp.int32
    for cfg in (DRAFT, TARGET, XL):
        P = spec((cfg.n_params(),))
        CSH = spec(cfg.cache_shape())
        S = cfg.maxlen
        ex(f"{cfg.name}_prefill",
           lambda fl, t, n, cfg=cfg: M.prefill(cfg, use_pallas, fl, t, n),
           (P, spec((S,), i32), spec((), i32)))

        # target/xl also export g1: the paper-faithful stepwise AR baseline
        # (one dispatch per token, like HF sampling with a KV cache) next to
        # the scan-fused g16 chunk variant.
        gen_cs = C_LIST if cfg.name == "draft" else [1]
        gen_gs = G_LIST if cfg.name == "draft" else [1, AR_CHUNK]
        for c in gen_cs:
            for g in gen_gs:
                ex(f"{cfg.name}_generate_c{c}_g{g}",
                   lambda fl, ca, fe, nf, po, u, T, tp, cfg=cfg, c=c, g=g:
                       M.generate_block(cfg, c, g, use_pallas, fl, ca, fe, nf, po, u, T, tp),
                   (P, CSH, spec((g + 1,), i32), spec((), i32), spec((), i32),
                    spec((c, g)), spec(()), spec(())))

        if cfg.name in ("target", "xl"):
            for g in G_LIST:
                ex(f"{cfg.name}_verify_g{g}",
                   lambda fl, ca, t, po, T, tp, cfg=cfg, g=g:
                       M.verify_block(cfg, g, use_pallas, fl, ca, t, po, T, tp),
                   (P, CSH, spec((g + 1,), i32), spec((), i32), spec(()), spec(())))

    for name in ("target", "draft"):
        cfg = CONFIGS[name]
        P = spec((cfg.n_params(),))
        ex(f"{name}_score",
           lambda fl, t, n, cfg=cfg: M.score_seq(cfg, fl, t, n),
           (P, spec((cfg.maxlen,), i32), spec((), i32)))
    ex("target_embed",
       lambda fl, t, n: M.embed_seq(TARGET, fl, t, n),
       (spec((TARGET.n_params(),)), spec((TARGET.maxlen,), i32), spec((), i32)))

    from .kernels.kmer_score import kmer_score
    for g in G_LIST:
        ex(f"kmer_score_c8_g{g}",
           lambda ca, p1, p3, p5, km: (kmer_score(ca, p1, p3, p5, km),),
           (spec((8, g), i32), spec((KV,)), spec((KV ** 3,)), spec((HSZ,)),
            spec((3,))))
    print(f"[aot] exported {total//1024} KiB of HLO text")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(HERE, "..", "..", "artifacts"))
    ap.add_argument("--fast", action="store_true", help="tiny training run (smoke)")
    ap.add_argument("--stage", choices=["all", "data", "train", "export"], default="all")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)

    stamp = content_stamp(f"fast={args.fast}")
    stamp_file = os.path.join(out, ".stamp")
    if (not args.force and args.stage == "all" and os.path.exists(stamp_file)
            and open(stamp_file).read() == stamp):
        print("[aot] artifacts up to date (stamp match); nothing to do")
        return

    t0 = time.time()
    if args.stage in ("all", "data"):
        build_data(out)
    if args.stage in ("all", "train"):
        build_models(out, args.fast)
    if args.stage in ("all", "export"):
        export_programs(out)
    if args.stage == "all":
        with open(stamp_file, "w") as f:
            f.write(stamp)
    print(f"[aot] done in {time.time()-t0:.0f}s -> {out}")


if __name__ == "__main__":
    main()
