"""Layer-2: ProGen2-like decoder-only transformer in JAX.

Two checkpoints play the paper's ProGen2-S (draft) and ProGen2-M (target)
roles (plus an "xl" config for the Table-5 ProGen2-XL experiment).  The
model is deliberately classic: learned token+position embeddings, pre-LN
blocks, causal MHA, GELU MLP, weight-tied head.

The file defines two families of functions:

  * full-sequence forward (`forward`) used for training, scoring and
    embeddings — plain jnp attention (fast on CPU, differentiable);
  * cached incremental functions used by the exported serving programs —
    attention runs through the Pallas kernel (kernels/attention.py) when
    `use_pallas=True`, which is how aot.py lowers them.

Position/write-frontier convention (mirrored by rust/src/decode/*):
  the KV cache has one slot per absolute position; `prefill` feeds the
  first n-1 context tokens; thereafter every committed token is fed exactly
  once (as `feed` in `generate_block`, or inside `verify`) before any
  sampling continues.  Slots past the frontier may hold stale values; the
  attention mask (key_pos <= query_pos) plus strictly sequential rewrites
  make them unobservable.

Parameters travel as ONE flat f32 vector (arg 0 of every exported
program); `unflatten` carves it with static offsets. `manifest.json`
records the layout for the Rust side.
"""

import dataclasses
import functools
import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from . import vocab
from .kernels.attention import cached_attention

MAXLEN = 192  # max sequence length incl. BOS/EOS (families capped to fit)


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    n_layer: int
    d_model: int
    n_head: int
    d_ff: int
    vocab: int = vocab.VOCAB
    maxlen: int = MAXLEN

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_head

    # ---- flat parameter layout ------------------------------------------
    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        d, f, s, v = self.d_model, self.d_ff, self.maxlen, self.vocab
        specs = [("tok_emb", (v, d)), ("pos_emb", (s, d))]
        for l in range(self.n_layer):
            p = f"l{l}."
            specs += [
                (p + "ln1_g", (d,)), (p + "ln1_b", (d,)),
                (p + "wq", (d, d)), (p + "wk", (d, d)),
                (p + "wv", (d, d)), (p + "wo", (d, d)),
                (p + "ln2_g", (d,)), (p + "ln2_b", (d,)),
                (p + "w1", (d, f)), (p + "b1", (f,)),
                (p + "w2", (f, d)), (p + "b2", (d,)),
            ]
        specs += [("lnf_g", (d,)), ("lnf_b", (d,))]
        return specs

    def n_params(self) -> int:
        return sum(int(math.prod(s)) for _, s in self.param_specs())

    def cache_shape(self) -> Tuple[int, ...]:
        # [layer, k/v, head, position, d_head]
        return (self.n_layer, 2, self.n_head, self.maxlen, self.d_head)


# Sizes chosen for the single-core CPU testbed: what matters for the
# paper's dynamics is the draft/target quality gap and the ~5x cost ratio
# (ProGen2-S:M is 151M:764M ≈ 1:5), not absolute scale. draft:target here
# is 67k:356k ≈ 1:5.3; xl is the Table-5 ProGen2-XL stand-in.
DRAFT = ModelCfg("draft", n_layer=2, d_model=48, n_head=2, d_ff=192)
TARGET = ModelCfg("target", n_layer=3, d_model=96, n_head=3, d_ff=384)
XL = ModelCfg("xl", n_layer=5, d_model=128, n_head=4, d_ff=512)
CONFIGS = {c.name: c for c in (DRAFT, TARGET, XL)}


def init_params(cfg: ModelCfg, key) -> jnp.ndarray:
    """Flat f32 parameter vector, GPT-2-style init."""
    chunks = []
    scale_out = 0.02 / math.sqrt(2 * cfg.n_layer)
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        base = name.split(".")[-1]
        if base in ("ln1_g", "ln2_g", "lnf_g"):
            w = jnp.ones(shape)
        elif base in ("ln1_b", "ln2_b", "lnf_b", "b1", "b2"):
            w = jnp.zeros(shape)
        elif base in ("wo", "w2"):
            w = jax.random.normal(sub, shape) * scale_out
        else:
            w = jax.random.normal(sub, shape) * 0.02
        chunks.append(w.reshape(-1))
    return jnp.concatenate(chunks).astype(jnp.float32)


def unflatten(cfg: ModelCfg, flat: jnp.ndarray) -> dict:
    out, off = {}, 0
    for name, shape in cfg.param_specs():
        n = int(math.prod(shape))
        out[name] = jax.lax.dynamic_slice(flat, (off,), (n,)).reshape(shape)
        off += n
    return out


def _ln(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _split_heads(x, n_head):  # [..., T, D] -> [..., H, T, Dh]
    *lead, t, d = x.shape
    return x.reshape(*lead, t, n_head, d // n_head).swapaxes(-3, -2)


def _merge_heads(x):  # [..., H, T, Dh] -> [..., T, D]
    *lead, h, t, dh = x.shape
    return x.swapaxes(-3, -2).reshape(*lead, t, h * dh)


# --------------------------------------------------------------------------
# Full-sequence forward (training / scoring / embedding).
# --------------------------------------------------------------------------

def forward(cfg: ModelCfg, flat, tokens):
    """tokens [B,T] int32 -> (logits [B,T,V], final hidden [B,T,D])."""
    p = unflatten(cfg, flat)
    b, t = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][:t][None]
    mask = jnp.tril(jnp.ones((t, t), bool))
    for l in range(cfg.n_layer):
        q = f"l{l}."
        h = _ln(x, p[q + "ln1_g"], p[q + "ln1_b"])
        qh = _split_heads(h @ p[q + "wq"], cfg.n_head)
        kh = _split_heads(h @ p[q + "wk"], cfg.n_head)
        vh = _split_heads(h @ p[q + "wv"], cfg.n_head)
        s = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / math.sqrt(cfg.d_head)
        s = jnp.where(mask[None, None], s, -1e30)
        a = jax.nn.softmax(s, axis=-1)
        x = x + _merge_heads(jnp.einsum("bhqk,bhkd->bhqd", a, vh)) @ p[q + "wo"]
        h = _ln(x, p[q + "ln2_g"], p[q + "ln2_b"])
        x = x + (jax.nn.gelu(h @ p[q + "w1"] + p[q + "b1"])) @ p[q + "w2"] + p[q + "b2"]
    x = _ln(x, p["lnf_g"], p["lnf_b"])
    logits = x @ p["tok_emb"].T  # weight-tied head
    return logits, x


# --------------------------------------------------------------------------
# Cached incremental forward (exported serving programs).
# --------------------------------------------------------------------------

def _cached_block(cfg, p, l, x, cache, pos0, qpos, use_pallas):
    """One transformer block over G new tokens with KV-cache update.

    x [B,G,D]; cache [B,L,2,H,S,Dh]; writes K/V at absolute positions
    pos0..pos0+G-1; queries attend with key_pos <= qpos[g].
    Returns (x', cache').
    """
    q = f"l{l}."
    h = _ln(x, p[q + "ln1_g"], p[q + "ln1_b"])
    qh = _split_heads(h @ p[q + "wq"], cfg.n_head)  # [B,H,G,Dh]
    kh = _split_heads(h @ p[q + "wk"], cfg.n_head)
    vh = _split_heads(h @ p[q + "wv"], cfg.n_head)
    # write the new K/V rows at the frontier
    kv = jnp.stack([kh, vh], axis=1)[:, None]  # [B,1,2,H,G,Dh]
    cache = jax.lax.dynamic_update_slice(cache, kv, (0, l, 0, 0, pos0, 0))
    k_all = cache[:, l, 0]  # [B,H,S,Dh]
    v_all = cache[:, l, 1]
    if use_pallas:
        att = cached_attention(qh, k_all, v_all, qpos)
    else:
        from .kernels.ref import ref_cached_attention
        att = ref_cached_attention(qh, k_all, v_all, qpos)
    x = x + _merge_heads(att) @ p[q + "wo"]
    h = _ln(x, p[q + "ln2_g"], p[q + "ln2_b"])
    x = x + (jax.nn.gelu(h @ p[q + "w1"] + p[q + "b1"])) @ p[q + "w2"] + p[q + "b2"]
    return x, cache


def _cached_forward(cfg, p, tokens, cache, pos0, qpos, use_pallas):
    """tokens [B,G] at positions pos0..pos0+G-1 -> (logits [B,G,V], cache')."""
    g = tokens.shape[1]
    pos_ids = pos0 + jnp.arange(g)
    x = p["tok_emb"][tokens] + p["pos_emb"][pos_ids][None]
    for l in range(cfg.n_layer):
        x, cache = _cached_block(cfg, p, l, x, cache, pos0, qpos, use_pallas)
    x = _ln(x, p["lnf_g"], p["lnf_b"])
    return x @ p["tok_emb"].T, cache


def adjust_dist(logits, temp, top_p):
    """Temperature + nucleus truncation -> full renormalized dist [.., V].

    Keeps the smallest prefix of the descending-sorted probabilities whose
    exclusive cumulative sum is < top_p (the first token always survives).
    Mirrors rust/src/sampling.rs exactly.
    """
    probs = jax.nn.softmax(logits / temp, axis=-1)
    sp = jnp.sort(probs, axis=-1)[..., ::-1]
    cum = jnp.cumsum(sp, axis=-1)
    # threshold = probability of the last kept token
    keep_sorted = (cum - sp) < top_p
    # a prob is kept iff it is >= the smallest kept sorted prob
    thresh = jnp.min(jnp.where(keep_sorted, sp, jnp.inf), axis=-1, keepdims=True)
    kept = probs >= thresh
    probs = jnp.where(kept, probs, 0.0)
    return probs / probs.sum(-1, keepdims=True)


def sample_from_dist(dist, u):
    """Inverse-CDF draw. dist [..,V], u [..] in [0,1) -> int32 token [..]."""
    cum = jnp.cumsum(dist, axis=-1)
    idx = jnp.sum((cum < u[..., None]).astype(jnp.int32), axis=-1)
    return jnp.minimum(idx, dist.shape[-1] - 1)


# ---- exported programs ----------------------------------------------------

def prefill(cfg: ModelCfg, use_pallas: bool, flat, tokens, n_ctx):
    """Feed the first n_ctx-1 context tokens; return the cache.

    tokens [S] int32 (padded), n_ctx scalar int32.  All S positions are
    processed (cheap, one dispatch); slots >= n_ctx-1 hold garbage that the
    frontier convention keeps unobservable.
    """
    p = unflatten(cfg, flat)
    s = cfg.maxlen
    cache = jnp.zeros((1,) + cfg.cache_shape(), jnp.float32)
    qpos = jnp.arange(s, dtype=jnp.int32)
    _logits, cache = _cached_forward(cfg, p, tokens[None], cache, 0, qpos, use_pallas)
    del n_ctx  # layout is position-indexed; n_ctx kept for interface clarity
    return (cache[0],)


def generate_block(cfg: ModelCfg, n_cand: int, gamma: int, use_pallas: bool,
                   flat, cache, feed, n_feed, pos, u, temp, top_p):
    """Feed committed tokens, then draft `gamma` tokens for `n_cand` candidates.

    Args:
      cache: [L,2,H,S,Dh] committed cache (batch dim dropped).
      feed:  [gamma+1] int32 — tokens committed since the last call, padded.
      n_feed: scalar int32 in [1, gamma+1].
      pos:   scalar int32 — absolute position of feed[0] (= #tokens fed so far).
      u:     [n_cand, gamma] f32 uniforms (Rust-supplied randomness).
      temp, top_p: scalar f32 sampling knobs.
    Returns:
      toks  [n_cand, gamma] int32 sampled candidate tokens,
      dists [n_cand, gamma, V] the adjusted distributions each token was
            sampled from (the `p_i` of Algorithm 1),
      cache' [L,2,H,S,Dh] — committed cache after the feed (candidate KV is
            deliberately NOT returned; accepted tokens are re-fed next call).
    """
    p = unflatten(cfg, flat)
    f = gamma + 1
    # ---- phase 1: teacher-force the committed-but-unfed tokens -----------
    qpos = pos + jnp.arange(f, dtype=jnp.int32)
    logits_f, cache1 = _cached_forward(cfg, p, feed[None], cache[None], pos, qpos, use_pallas)
    last_logits = jnp.take_along_axis(
        logits_f[0], (n_feed - 1)[None, None], axis=0)[0]  # [V]
    # ---- phase 2: branch into candidates, scan gamma sampling steps ------
    ccache = jnp.broadcast_to(cache1, (n_cand,) + cache1.shape[1:])
    start = pos + n_feed  # first sampled position

    def step(carry, g_u):
        cache_c, logits = carry
        g, u_g = g_u
        dist = adjust_dist(logits, temp, top_p)          # [C,V]
        tok = sample_from_dist(dist, u_g)                # [C]
        qp = (start + g)[None].astype(jnp.int32)
        logits_n, cache_c = _cached_forward(
            cfg, p, tok[:, None], cache_c, start + g, qp, use_pallas)
        return (cache_c, logits_n[:, 0]), (tok, dist)

    init_logits = jnp.broadcast_to(last_logits, (n_cand, cfg.vocab))
    (_, _), (toks, dists) = jax.lax.scan(
        step, (ccache, init_logits),
        (jnp.arange(gamma, dtype=jnp.int32), u.T))
    return toks.T, dists.swapaxes(0, 1), cache1[0]


def verify_block(cfg: ModelCfg, gamma: int, use_pallas: bool,
                 flat, cache, toks, pos, temp, top_p):
    """Teacher-forced verification over gamma draft tokens + bonus position.

    toks [gamma+1]: toks[0] is the last committed-but-unfed token, toks[1:]
    the selected candidate's draft tokens.  Returns the adjusted target
    distributions q_i at every one of the gamma+1 prediction positions
    (dists[i] predicts the token after toks[i]; dists[gamma] is the bonus
    distribution) and the updated cache.
    """
    p = unflatten(cfg, flat)
    f = gamma + 1
    qpos = pos + jnp.arange(f, dtype=jnp.int32)
    logits, cache1 = _cached_forward(cfg, p, toks[None], cache[None], pos, qpos, use_pallas)
    dists = adjust_dist(logits[0], temp, top_p)  # [gamma+1, V]
    return dists, cache1[0]


def score_seq(cfg: ModelCfg, flat, tokens, n):
    """Per-position NLL of tokens[1..n-1] under the model (no temp/top-p).

    Returns nll [S] with nll[i] = -log softmax(logits[i-1])[tokens[i]] for
    1 <= i < n and 0 elsewhere — the paper's length-normalized NLL is
    sum(nll)/(n-1) on the Rust side.
    """
    logits, _ = forward(cfg, flat, tokens[None])
    logp = jax.nn.log_softmax(logits[0], axis=-1)  # [S,V]
    s = tokens.shape[0]
    tgt = tokens[1:]
    nll_body = -jnp.take_along_axis(logp[:-1], tgt[:, None], axis=1)[:, 0]
    nll = jnp.concatenate([jnp.zeros((1,)), nll_body])
    idx = jnp.arange(s)
    return (jnp.where((idx >= 1) & (idx < n), nll, 0.0),)


def embed_seq(cfg: ModelCfg, flat, tokens, n):
    """Mean-pooled final hidden state over the first n positions [D]."""
    _, hid = forward(cfg, flat, tokens[None])
    s = tokens.shape[0]
    m = (jnp.arange(s) < n).astype(jnp.float32)[:, None]
    return ((hid[0] * m).sum(0) / jnp.maximum(m.sum(), 1.0),)
