"""Pallas fused causal attention over a KV cache — the L1 hot-spot kernel.

The paper's compute hot spot is transformer decode on an A6000 GPU.  The
TPU-style rethink (DESIGN.md §7): one fused kernel computes QKᵀ → masked,
numerically-stable softmax → PV without leaving VMEM, with the grid laid
out over (batch, heads) and the KV cache staged HBM→VMEM per head.  With
our S ≤ 256 the whole per-head KV slab (S×Dh×4B ≤ 32 KiB) fits in a single
VMEM block, so no cross-block flash accumulation is needed; the BlockSpec
still expresses the HBM→VMEM schedule a longer-sequence variant would tile.

Masking is positional: query g (absolute position qpos[g]) may attend keys
at cache slots ≤ qpos[g].  Slots past the write frontier contain stale data
by design (see model.py) and are always masked or overwritten first.

Kernels MUST run with interpret=True here (CPU PJRT cannot execute Mosaic
custom-calls); `force_interpret` exists so tests can assert both paths
trace identically.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(qpos_ref, q_ref, k_ref, v_ref, o_ref, *, scale: float):
    """One (batch, head) tile: q [G,Dh] against the full KV slab [S,Dh]."""
    q = q_ref[0, 0]  # [G, Dh] — VMEM block
    k = k_ref[0, 0]  # [S, Dh]
    v = v_ref[0, 0]  # [S, Dh]
    qpos = qpos_ref[:]  # [G] absolute positions of the queries

    # MXU-shaped contraction; f32 accumulate (bf16 inputs on real TPU).
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [G,S]
    kidx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kidx <= qpos[:, None], s, -1e30)

    # Numerically-stable softmax, fused in-register.
    m = jnp.max(s, axis=1, keepdims=True)
    e = jnp.exp(s - m)
    z = jnp.sum(e, axis=1, keepdims=True)
    o_ref[0, 0] = jnp.dot(e, v, preferred_element_type=jnp.float32) / z


def cached_attention(q, k, v, qpos, *, force_interpret: bool = True):
    """Fused causal attention over a KV cache.

    Args:
      q:    [B, H, G, Dh] queries for G new positions.
      k, v: [B, H, S, Dh] full cache slabs (S = model max length).
      qpos: [G] int32 absolute positions of the G queries.
    Returns:
      [B, H, G, Dh] attention outputs.
    """
    b, h, g, dh = q.shape
    s = k.shape[2]
    kern = functools.partial(_attn_kernel, scale=1.0 / math.sqrt(dh))
    return pl.pallas_call(
        kern,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((g,), lambda bi, hi: (0,)),
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda bi, hi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda bi, hi: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, g, dh), jnp.float32),
        interpret=force_interpret,
    )(qpos, q, k, v)


def vmem_bytes(g: int, s: int, dh: int) -> int:
    """Estimated VMEM footprint of one grid step (see EXPERIMENTS.md §Perf)."""
    f = 4  # f32; 2 on real TPU with bf16 inputs
    return f * (g * dh + 2 * s * dh + g * s + g * dh)  # q + kv + scores + out
