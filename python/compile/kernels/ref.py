"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written in
the most obvious way possible; pytest (python/tests/) sweeps shapes/dtypes
with hypothesis and asserts allclose between kernel and oracle.
"""

import math

import jax
import jax.numpy as jnp

from .kmer_score import HSZ, V, hash5


def ref_cached_attention(q, k, v, qpos):
    """Oracle for attention.cached_attention.

    q: [B,H,G,Dh], k/v: [B,H,S,Dh], qpos: [G] int32 -> [B,H,G,Dh]
    """
    dh = q.shape[-1]
    s = jnp.einsum("bhgd,bhsd->bhgs", q, k) / math.sqrt(dh)
    kidx = jnp.arange(k.shape[2])[None, None, None, :]
    mask = kidx <= qpos[None, None, :, None]
    s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", w, v)


def ref_kmer_score(cands, p1, p3, p5, kmask):
    """Oracle for kmer_score.kmer_score. cands: [C,G] -> [C]."""
    c, g = cands.shape
    out = []
    for ci in range(c):
        t = cands[ci]
        s1 = jnp.sum(p1[t])
        s3 = jnp.float32(0.0)
        if g >= 3:
            for i in range(g - 2):
                idx = (t[i] * V + t[i + 1]) * V + t[i + 2]
                s3 = s3 + p3[idx]
        s5 = jnp.float32(0.0)
        if g >= 5:
            for i in range(g - 4):
                h = hash5(jnp.asarray(t[i]), jnp.asarray(t[i + 1]),
                          jnp.asarray(t[i + 2]), jnp.asarray(t[i + 3]),
                          jnp.asarray(t[i + 4]))
                s5 = s5 + p5[h]
        out.append((kmask[0] * s1 + kmask[1] * s3 + kmask[2] * s5) / g)
    return jnp.stack(out)
