"""Pallas k-mer scoring kernel (Eq. 2 of the paper).

Scores C candidate draft blocks of length G against MSA-derived k-mer
frequency tables:

    Score(s) = (1/G) * sum_{k in K} sum_i  P_k( s[i : i+k] )

Tables are dense for k=1 (V) and k=3 (V^3 = 32768 floats) and
open-addressed-hashed for k=5 (HSZ = 2^18 slots; V^5 would be 33M entries).
The hash is plain base-33 rolling * Knuth multiplier in wrapping uint32
arithmetic and MUST match `rust/src/kmer/table.rs` bit-for-bit — both sides
fold colliding 5-mers into the same slot, so scores agree exactly.

Grid is over candidates; all tables live in VMEM for the duration of the
block (k3 table = 128 KiB, k5 table = 1 MiB — the dominant VMEM tenant,
recorded in EXPERIMENTS.md §Perf).
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

V = 32
HSZ = 1 << 18  # k=5 hash table slots
HASH_MUL = np.uint32(2654435761)  # numpy scalar: inlined, not captured


def hash5(t0, t1, t2, t3, t4):
    """Wrapping-u32 hash of a 5-mer; identical to the Rust implementation."""
    h = t0.astype(jnp.uint32)
    for t in (t1, t2, t3, t4):
        h = h * np.uint32(33) + t.astype(jnp.uint32)
    return (h * HASH_MUL) & np.uint32(HSZ - 1)


def _kmer_kernel(cand_ref, p1_ref, p3_ref, p5_ref, kmask_ref, o_ref):
    t = cand_ref[0]  # [G] int32 tokens of this candidate
    g = t.shape[0]
    p1 = p1_ref[:]
    p3 = p3_ref[:]
    p5 = p5_ref[:]
    kmask = kmask_ref[:]  # [3] f32 — which k's are active (1.0/0.0)

    s1 = jnp.sum(p1[t])

    if g >= 3:
        idx3 = (t[:-2] * V + t[1:-1]) * V + t[2:]
        s3 = jnp.sum(p3[idx3])
    else:
        s3 = jnp.float32(0.0)

    if g >= 5:
        h = hash5(t[: g - 4], t[1 : g - 3], t[2 : g - 2], t[3 : g - 1], t[4:g])
        s5 = jnp.sum(p5[h])
    else:
        s5 = jnp.float32(0.0)

    o_ref[0] = (kmask[0] * s1 + kmask[1] * s3 + kmask[2] * s5) / g


def kmer_score(cands, p1, p3, p5, kmask, *, force_interpret: bool = True):
    """Score candidate blocks.

    Args:
      cands: [C, G] int32 candidate tokens.
      p1:    [V]    f32 normalized 1-mer probabilities.
      p3:    [V^3]  f32 flattened 3-mer probabilities.
      p5:    [HSZ]  f32 hashed 5-mer probabilities.
      kmask: [3]    f32 per-k on/off weights.
    Returns:
      [C] f32 scores.
    """
    c, g = cands.shape
    return pl.pallas_call(
        _kmer_kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, g), lambda ci: (ci, 0)),
            pl.BlockSpec((V,), lambda ci: (0,)),
            pl.BlockSpec((V * V * V,), lambda ci: (0,)),
            pl.BlockSpec((HSZ,), lambda ci: (0,)),
            pl.BlockSpec((3,), lambda ci: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda ci: (ci,)),
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        interpret=force_interpret,
    )(cands, p1, p3, p5, kmask)
