"""Shared token vocabulary for the SpecMER reproduction.

Mirrors `rust/src/tokenizer.rs` exactly — both sides must agree on ids.

Layout (V = 32, padded so gathers/one-hots stay power-of-two sized):
  0  PAD
  1  BOS   (ProGen2 uses "1" as the N-terminus token; we call it BOS)
  2  EOS   (ProGen2's stop token is literally "2" — see paper App. B.3)
  3..22    the 20 canonical amino acids, alphabetical by letter
  23 X     unknown / any
  24..31   unused (reserved)
"""

PAD = 0
BOS = 1
EOS = 2
AA = "ACDEFGHIKLMNPQRSTVWY"  # 20 canonical amino acids
X = 23
VOCAB = 32
AA_OFFSET = 3

TOK_OF = {a: AA_OFFSET + i for i, a in enumerate(AA)}
TOK_OF["X"] = X
CHR_OF = {v: k for k, v in TOK_OF.items()}


def encode(seq: str) -> list:
    """Amino-acid string -> token ids (no BOS/EOS added)."""
    return [TOK_OF.get(ch, X) for ch in seq.upper() if ch != "-" and ch != "."]


def decode(toks) -> str:
    """Token ids -> amino-acid string. Skips special tokens."""
    return "".join(CHR_OF.get(int(t), "") for t in toks if int(t) >= AA_OFFSET)
