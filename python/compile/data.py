"""Synthetic protein-family generator — the ProteinGym-MSA stand-in.

The paper draws seven wild-type proteins and their multiple sequence
alignments (MSAs) from ProteinGym.  We cannot ship those, so we build a
profile-HMM-style simulator that produces, per family:

  * a wild-type sequence composed of conserved *motif blocks* separated by
    variable linker regions (this is what makes k-mers informative: motif
    columns have low substitution rates, so the family's k-mer spectrum is
    sharply peaked on motif k-mers);
  * an MSA of homologs sampled from the profile (per-column substitution
    rates, occasional gap characters so the A2M parser is exercised);
  * family metadata mirroring the paper's Table 1 (length, context length,
    MSA depth — lengths capped at MAXLEN-6 and depths scaled down, see
    DESIGN.md §3).

The same files are the canonical corpus for training the draft/target
models and, on the Rust side, for building k-mer tables — so "MSA-derived
k-mers describe what the target model likes" holds by construction, which
is the property SpecMER exploits.
"""

import json
import os

import numpy as np

from . import vocab

MAXLEN = 192  # model max sequence length (BOS + seq + EOS must fit)

# name, paper_len, our_len, context_len, paper_depth, our_depth, function
# Lengths are capped at MAXLEN-12 and contexts kept at ~10% of our length
# (the paper's rule); depths scaled per DESIGN.md §3, GB1 kept shallow.
# Long-family cap of 168 leaves room for BOS/EOS plus a full final draft
# block (gamma <= 15) inside MAXLEN=192 KV slots.
FAMILIES = [
    ("GFP",   238, 168, 17, 396,    396,  "Fluorescence"),
    ("RBP1",   52,  52, 10, 135922, 3000, "Stability"),
    ("ParD3",  93,  93, 15, 38613,  3000, "Growth enrichment"),
    ("GB1",    56,  56, 10, 44,     44,   "Binding"),
    ("Bgl3",  501, 168, 17, 105913, 3000, "Enzyme function"),
    ("ADRB2", 413, 168, 17, 204722, 3000, "Receptor activity"),
    ("CBS",   551, 168, 17, 19563,  2000, "Growth"),
]

N_AA = 20

# Rough natural amino-acid background frequencies (Swiss-Prot order matched
# to vocab.AA = "ACDEFGHIKLMNPQRSTVWY").
BACKGROUND = np.array([
    0.0826, 0.0137, 0.0546, 0.0672, 0.0386, 0.0708, 0.0227, 0.0593, 0.0581,
    0.0965, 0.0241, 0.0406, 0.0474, 0.0393, 0.0553, 0.0660, 0.0535, 0.0686,
    0.0110, 0.0292,
])
BACKGROUND = BACKGROUND / BACKGROUND.sum()


def family_seed(name: str) -> int:
    return sum(ord(c) * 131 ** i for i, c in enumerate(name)) % (2**31)


def make_profile(rng: np.random.RandomState, length: int):
    """Per-column categorical distributions over the 20 AAs.

    Columns alternate between conserved motif blocks (a dominant residue
    holding 60–95% of the mass, biased toward helix/sheet formers) and
    variable linkers (Dirichlet-smeared background).  Returns
    (profile [length, 20], conservation [length]).
    """
    profile = np.zeros((length, N_AA))
    conservation = np.zeros(length)
    pos = 0
    motif = rng.rand() < 0.5  # start state
    while pos < length:
        block = int(rng.randint(4, 12) if motif else rng.randint(3, 10))
        block = min(block, length - pos)
        if motif:
            for i in range(pos, pos + block):
                dom = rng.randint(N_AA)
                w = 0.60 + 0.35 * rng.rand()
                p = (1 - w) * rng.dirichlet(np.ones(N_AA) * 0.5) + w * np.eye(N_AA)[dom]
                profile[i] = p
                conservation[i] = w
        else:
            for i in range(pos, pos + block):
                p = rng.dirichlet(BACKGROUND * 15.0)
                profile[i] = p
                conservation[i] = 0.1 + 0.2 * rng.rand()
        pos += block
        motif = not motif
    profile /= profile.sum(axis=1, keepdims=True)
    return profile, conservation


def sample_from_profile(rng, profile):
    """One homolog: per-column draw from the profile."""
    length = profile.shape[0]
    u = rng.rand(length, 1)
    cdf = np.cumsum(profile, axis=1)
    idx = (u > cdf).sum(axis=1)
    return np.minimum(idx, N_AA - 1)


def make_msa(name: str, length: int, depth: int, gap_rate: float = 0.02):
    """Build (wild_type, msa_rows) as index arrays in 0..19, gaps as -1."""
    rng = np.random.RandomState(family_seed(name))
    profile, cons = make_profile(rng, length)
    wt = profile.argmax(axis=1)  # consensus = wild type
    rows = []
    for _ in range(depth):
        row = sample_from_profile(rng, profile)
        gaps = rng.rand(length) < gap_rate * (1.0 - cons)  # gaps avoid motifs
        row = np.where(gaps, -1, row)
        rows.append(row)
    return wt, np.stack(rows), profile, cons


def idx_to_str(idx_row) -> str:
    return "".join("-" if i < 0 else vocab.AA[i] for i in idx_row)


def write_a2m(path: str, name: str, wt, rows):
    with open(path, "w") as f:
        f.write(f">{name}_WT\n{idx_to_str(wt)}\n")
        for j, row in enumerate(rows):
            f.write(f">{name}_{j}\n{idx_to_str(row)}\n")


def build_all(out_dir: str, verbose: bool = True):
    """Generate every family MSA + families.json manifest into out_dir/msa."""
    msa_dir = os.path.join(out_dir, "msa")
    os.makedirs(msa_dir, exist_ok=True)
    meta = []
    for name, paper_len, length, ctx, paper_depth, depth, func in FAMILIES:
        wt, rows, _, _ = make_msa(name, length, depth)
        write_a2m(os.path.join(msa_dir, f"{name}.a2m"), name, wt, rows)
        meta.append({
            "name": name, "paper_length": paper_len, "length": length,
            "context": ctx, "paper_msa_depth": paper_depth, "msa_depth": depth,
            "function": func, "wild_type": idx_to_str(wt),
        })
        if verbose:
            print(f"  msa {name}: len={length} depth={depth}")
    with open(os.path.join(out_dir, "families.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def training_corpus(out_dir: str, max_per_family: int = 1500, holdout: int = 32):
    """Tokenized training/holdout sequences from the generated MSAs.

    Every row is BOS + ungapped(seq) + EOS, as a python list of ids.
    The first `holdout` rows of each family are reserved for eval.
    """
    train, hold = [], []
    for name, _, length, _, _, depth, _ in FAMILIES:
        rng = np.random.RandomState(family_seed(name))
        _prof, _cons = make_profile(rng, length)  # consume same stream as make_msa
        # regenerate rows identically to make_msa
        wt, rows, _, _ = make_msa(name, length, depth)
        take = min(depth, max_per_family + holdout)
        sel = np.random.RandomState(family_seed(name) ^ 0x5EED).permutation(depth)[:take]
        for i, ri in enumerate(sel):
            row = rows[ri]
            toks = [vocab.BOS] + [vocab.AA_OFFSET + int(a) for a in row if a >= 0] + [vocab.EOS]
            (hold if i < holdout else train).append(toks)
    return train, hold


if __name__ == "__main__":
    import sys
    build_all(sys.argv[1] if len(sys.argv) > 1 else "../artifacts")
