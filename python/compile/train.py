"""Build-time training of the draft/target checkpoints (manual Adam).

The paper uses pretrained ProGen2-S/M; we train our stand-ins on the
synthetic family corpus (data.py).  Both models see the same data, the
bigger one fits it better — reproducing the draft≈target relation that
speculative decoding exploits.  The draft additionally gets a distillation
term toward the (frozen) target logits, mirroring how small/large ProGen2
checkpoints share a training distribution.

optax is unavailable in this image, so Adam is implemented inline.
"""

import math
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from . import vocab
from .model import ModelCfg, forward, init_params


def pad_batch(seqs: List[List[int]], maxlen: int) -> np.ndarray:
    out = np.full((len(seqs), maxlen), vocab.PAD, np.int32)
    for i, s in enumerate(seqs):
        s = s[:maxlen]
        out[i, : len(s)] = s
    return out


def lm_loss(cfg: ModelCfg, flat, tokens):
    """Causal LM cross-entropy, PAD positions masked out."""
    logits, _ = forward(cfg, flat, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp[:, :-1], tgt[:, :, None], axis=2)[:, :, 0]
    mask = (tgt != vocab.PAD).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def distill_loss(cfg_s: ModelCfg, flat_s, tokens, teacher_logits):
    """CE of the student against the teacher's softmax (plus data CE)."""
    logits, _ = forward(cfg_s, flat_s, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    soft = jax.nn.softmax(teacher_logits, axis=-1)
    mask = (tokens != vocab.PAD).astype(jnp.float32)[:, :, None]
    kd = -(soft * logp * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return lm_loss(cfg_s, flat_s, tokens) + kd


def adam_update(g, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1**t)
    vhat = v / (1 - b2**t)
    return -lr * mhat / (jnp.sqrt(vhat) + eps), m, v


def train_model(cfg: ModelCfg, train_seqs, hold_seqs, *, steps: int,
                batch: int = 16, lr: float = 1e-3, seed: int = 0,
                teacher=None, log_every: int = 100, maxlen: int = None):
    """Train one checkpoint; returns the flat param vector (numpy f32)."""
    maxlen = maxlen or cfg.maxlen
    key = jax.random.PRNGKey(seed)
    flat = init_params(cfg, key)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    rng = np.random.RandomState(seed + 1)

    if teacher is None:
        loss_fn = lambda f, toks, tl: lm_loss(cfg, f, toks)
    else:
        t_cfg, t_flat = teacher
        loss_fn = lambda f, toks, tl: distill_loss(cfg, f, toks, tl)

        @jax.jit
        def teacher_logits(toks):
            return forward(t_cfg, t_flat, toks)[0]

    @jax.jit
    def step_fn(flat, m, v, t, toks, tlogits):
        loss, g = jax.value_and_grad(loss_fn)(flat, toks, tlogits)
        upd, m, v = adam_update(g, m, v, t, lr)
        return flat + upd, m, v, loss

    @jax.jit
    def eval_fn(flat, toks):
        return lm_loss(cfg, flat, toks)

    hold = jnp.asarray(pad_batch(hold_seqs[:64], maxlen))
    dummy_tl = jnp.zeros((batch, maxlen, cfg.vocab), jnp.float32)
    t0 = time.time()
    for t in range(1, steps + 1):
        idx = rng.randint(0, len(train_seqs), size=batch)
        toks = jnp.asarray(pad_batch([train_seqs[i] for i in idx], maxlen))
        tl = teacher_logits(toks) if teacher is not None else dummy_tl
        flat, m, v, loss = step_fn(flat, m, v, jnp.float32(t), toks, tl)
        if t % log_every == 0 or t == steps:
            hl = eval_fn(flat, hold)
            print(f"  [{cfg.name}] step {t}/{steps} loss={float(loss):.4f} "
                  f"holdout={float(hl):.4f} ppl={math.exp(float(hl)):.2f} "
                  f"({time.time()-t0:.0f}s)")
    return np.asarray(flat, np.float32)
