"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and values; assert_allclose against ref.py is the
core correctness signal for the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import cached_attention, vmem_bytes
from compile.kernels.kmer_score import HSZ, V, hash5, kmer_score
from compile.kernels.ref import ref_cached_attention, ref_kmer_score


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.integers(1, 3),
    g=st.integers(1, 8),
    s=st.integers(8, 48),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, h, g, s, dh, seed):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(b, h, g, dh), jnp.float32)
    k = jnp.asarray(rng.randn(b, h, s, dh), jnp.float32)
    v = jnp.asarray(rng.randn(b, h, s, dh), jnp.float32)
    # positions strictly increasing within [0, s)
    base = rng.randint(0, max(1, s - g))
    qpos = jnp.asarray(base + np.arange(g), jnp.int32)
    out = cached_attention(q, k, v, qpos)
    ref = ref_cached_attention(q, k, v, qpos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_attention_masks_future_positions():
    """Garbage beyond the query position must not leak into the output."""
    rng = np.random.RandomState(0)
    b, h, g, s, dh = 1, 1, 2, 16, 8
    q = jnp.asarray(rng.randn(b, h, g, dh), jnp.float32)
    k = np.asarray(rng.randn(b, h, s, dh), np.float32)
    v = np.asarray(rng.randn(b, h, s, dh), np.float32)
    qpos = jnp.asarray([4, 5], jnp.int32)
    out1 = cached_attention(q, jnp.asarray(k), jnp.asarray(v), qpos)
    # trash the masked region
    k2, v2 = k.copy(), v.copy()
    k2[:, :, 6:] = 1e6
    v2[:, :, 6:] = -1e6
    out2 = cached_attention(q, jnp.asarray(k2), jnp.asarray(v2), qpos)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


def test_attention_under_jit_and_grad_path():
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(2, 2, 3, 8), jnp.float32)
    k = jnp.asarray(rng.randn(2, 2, 16, 8), jnp.float32)
    v = jnp.asarray(rng.randn(2, 2, 16, 8), jnp.float32)
    qpos = jnp.asarray([3, 4, 5], jnp.int32)
    jitted = jax.jit(lambda *a: cached_attention(*a))
    np.testing.assert_allclose(
        np.asarray(jitted(q, k, v, qpos)),
        np.asarray(cached_attention(q, k, v, qpos)),
        rtol=1e-6,
    )


def test_vmem_estimate_monotone():
    assert vmem_bytes(16, 256, 32) > vmem_bytes(8, 128, 32)


@settings(max_examples=10, deadline=None)
@given(
    c=st.integers(1, 8),
    g=st.sampled_from([5, 10, 15]),
    seed=st.integers(0, 2**31 - 1),
    k1=st.booleans(),
    k3=st.booleans(),
    k5=st.booleans(),
)
def test_kmer_kernel_matches_ref(c, g, seed, k1, k3, k5):
    rng = np.random.RandomState(seed)
    cands = jnp.asarray(rng.randint(0, V, (c, g)), jnp.int32)
    p1 = jnp.asarray(rng.rand(V), jnp.float32)
    p3 = jnp.asarray(rng.rand(V**3), jnp.float32)
    p5 = jnp.asarray(rng.rand(HSZ), jnp.float32)
    km = jnp.asarray([float(k1), float(k3), float(k5)], jnp.float32)
    out = kmer_score(cands, p1, p3, p5, km)
    ref = ref_kmer_score(np.asarray(cands), p1, p3, p5, km)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6)


def test_hash5_contract_values():
    """Anchor values for the Rust-side hash (kmer/table.rs mirrors these)."""
    def py_hash(ts):
        h = np.uint32(ts[0])
        for t in ts[1:]:
            h = np.uint32((int(h) * 33 + t) & 0xFFFFFFFF)
        return (int(h) * 2654435761 & 0xFFFFFFFF) & (HSZ - 1)

    for ts in [(3, 4, 5, 6, 3), (0, 0, 0, 0, 0), (31, 31, 31, 31, 31), (7, 1, 2, 9, 30)]:
        got = int(hash5(*[jnp.asarray(t, jnp.int32) for t in ts]))
        assert got == py_hash(ts), ts


def test_kmer_zero_mask_gives_zero():
    cands = jnp.zeros((2, 5), jnp.int32)
    z = kmer_score(
        cands,
        jnp.ones(V, jnp.float32),
        jnp.ones(V**3, jnp.float32),
        jnp.ones(HSZ, jnp.float32),
        jnp.zeros(3, jnp.float32),
    )
    np.testing.assert_allclose(np.asarray(z), 0.0)
