"""Data generator and AOT export plumbing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, data, vocab
from compile.model import ModelCfg, init_params
from compile import model as M


def test_families_fit_model():
    for name, _plen, length, ctx, _pd, _d, _f in data.FAMILIES:
        assert length + 2 + 15 <= M.MAXLEN, f"{name} too long for maxlen+gamma"
        assert 1 <= ctx < length


def test_msa_generation_deterministic():
    wt1, rows1, _, _ = data.make_msa("GB1", 56, 44)
    wt2, rows2, _, _ = data.make_msa("GB1", 56, 44)
    np.testing.assert_array_equal(wt1, wt2)
    np.testing.assert_array_equal(rows1, rows2)
    assert rows1.shape == (44, 56)


def test_msa_conservation_structure():
    """Motif columns should dominate: many columns nearly unanimous."""
    _wt, rows, profile, cons = data.make_msa("GFP", 168, 200)
    col_match = (rows == profile.argmax(1)[None, :]).mean(0)
    # conserved columns (cons>0.8) agree with consensus far more often
    hi = col_match[cons > 0.85].mean()
    lo = col_match[cons < 0.4].mean()
    assert hi > lo + 0.2, (hi, lo)


def test_write_and_tokenize_roundtrip(tmp_path):
    wt, rows, _, _ = data.make_msa("GB1", 56, 10)
    p = tmp_path / "t.a2m"
    data.write_a2m(str(p), "GB1", wt, rows)
    text = p.read_text()
    assert text.count(">") == 11
    first = text.splitlines()[1]
    assert len(first) == 56
    toks = vocab.encode(first)
    assert all(3 <= t <= 23 for t in toks)


def test_training_corpus_shapes():
    train, hold = data.training_corpus("/tmp/unused", max_per_family=5, holdout=2)
    assert len(train) == 7 * 5
    assert len(hold) == 7 * 2
    for seq in train[:10]:
        assert seq[0] == vocab.BOS and seq[-1] == vocab.EOS
        assert len(seq) <= M.MAXLEN


def test_hlo_text_exports_and_parses(tmp_path):
    """Smoke the full export path for one tiny program."""
    tiny = ModelCfg("tiny", n_layer=1, d_model=16, n_head=2, d_ff=32, maxlen=32)
    out = tmp_path / "prog.hlo.txt"
    n = aot.export(
        lambda fl, t, nn: M.score_seq(tiny, fl, t, nn),
        (aot.spec((tiny.n_params(),)), aot.spec((32,), jnp.int32), aot.spec((), jnp.int32)),
        str(out),
    )
    assert n > 1000
    text = out.read_text()
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_content_stamp_changes_with_config():
    a = aot.content_stamp("fast=True")
    b = aot.content_stamp("fast=False")
    assert a != b and len(a) == 16


def test_export_list_covers_paper_grid():
    assert set(aot.G_LIST) == {5, 10, 15}
    assert set(aot.C_LIST) >= {1, 2, 3, 5}
