"""Training loop sanity: loss decreases, Adam updates finite, distill runs."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import vocab
from compile.model import ModelCfg, forward, init_params
from compile.train import adam_update, lm_loss, pad_batch, train_model

TINY = ModelCfg("tiny", n_layer=1, d_model=16, n_head=2, d_ff=32, maxlen=32)
TEACHER = ModelCfg("teach", n_layer=1, d_model=24, n_head=2, d_ff=48, maxlen=32)


def toy_corpus(n=64, length=20, seed=0):
    """Highly regular sequences: BOS + repeated motif + EOS."""
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(n):
        motif = [3, 4, 5, 6]
        seq = [vocab.BOS] + (motif * 8)[: length - 2] + [vocab.EOS]
        if rng.rand() < 0.3:
            seq[3] = 7  # slight variation
        out.append(seq)
    return out


def test_pad_batch():
    b = pad_batch([[1, 2], [1, 2, 3, 4]], 6)
    assert b.shape == (2, 6)
    assert b[0, 2] == vocab.PAD
    assert b[1, 3] == 4


def test_lm_loss_masks_pad():
    params = init_params(TINY, jax.random.PRNGKey(0))
    toks = jnp.asarray(pad_batch([[1, 5, 6, 2]], 8))
    l1 = lm_loss(TINY, params, toks)
    # adding more padding must not change the loss
    toks2 = jnp.asarray(pad_batch([[1, 5, 6, 2]], 12)[:, :8])
    l2 = lm_loss(TINY, params, toks2)
    assert abs(float(l1) - float(l2)) < 1e-6
    assert float(l1) > 0


def test_adam_update_direction():
    g = jnp.asarray([1.0, -2.0, 0.0])
    m = jnp.zeros(3)
    v = jnp.zeros(3)
    upd, m2, v2 = adam_update(g, m, v, jnp.float32(1.0), 0.1)
    assert float(upd[0]) < 0 and float(upd[1]) > 0 and abs(float(upd[2])) < 1e-9
    assert jnp.all(jnp.isfinite(m2)) and jnp.all(jnp.isfinite(v2))


def test_training_reduces_loss():
    corpus = toy_corpus()
    flat = train_model(TINY, corpus, corpus[:8], steps=30, batch=8, lr=3e-3,
                       seed=1, log_every=1000, maxlen=24)
    init = init_params(TINY, jax.random.PRNGKey(1))
    toks = jnp.asarray(pad_batch(corpus[:16], 24))
    before = float(lm_loss(TINY, init, toks))
    after = float(lm_loss(TINY, jnp.asarray(flat), toks))
    assert after < before - 0.3, (before, after)


def test_distillation_runs_and_learns():
    corpus = toy_corpus()
    teacher_flat = train_model(TEACHER, corpus, corpus[:8], steps=25, batch=8,
                               lr=3e-3, seed=2, log_every=1000, maxlen=24)
    student = train_model(TINY, corpus, corpus[:8], steps=20, batch=8, lr=3e-3,
                          seed=3, teacher=(TEACHER, jnp.asarray(teacher_flat)),
                          log_every=1000, maxlen=24)
    toks = jnp.asarray(pad_batch(corpus[:16], 24))
    init = init_params(TINY, jax.random.PRNGKey(3))
    assert float(lm_loss(TINY, jnp.asarray(student), toks)) < float(lm_loss(TINY, init, toks))
