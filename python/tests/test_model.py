"""L2 model semantics: causality, cached-vs-full consistency, sampling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import vocab
from compile.model import (
    DRAFT, TARGET, ModelCfg, adjust_dist, forward, generate_block,
    init_params, prefill, sample_from_dist, score_seq, verify_block, embed_seq,
)

TINY = ModelCfg("tiny", n_layer=2, d_model=32, n_head=2, d_ff=64, maxlen=64)


@pytest.fixture(scope="module")
def params():
    return init_params(TINY, jax.random.PRNGKey(0))


def rand_tokens(n, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randint(3, 23, (n,)), jnp.int32)


def test_param_count_matches_spec(params):
    assert params.shape[0] == TINY.n_params()


def test_forward_shapes(params):
    toks = rand_tokens(10)[None]
    logits, hidden = forward(TINY, params, toks)
    assert logits.shape == (1, 10, TINY.vocab)
    assert hidden.shape == (1, 10, TINY.d_model)


def test_causality(params):
    """Changing a future token must not affect earlier logits."""
    toks = np.asarray(rand_tokens(12, 1))
    a, _ = forward(TINY, params, jnp.asarray(toks)[None])
    toks2 = toks.copy()
    toks2[8] = (toks2[8] - 3 + 1) % 20 + 3
    b, _ = forward(TINY, params, jnp.asarray(toks2)[None])
    np.testing.assert_allclose(np.asarray(a[0, :8]), np.asarray(b[0, :8]), rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(a[0, 8:]), np.asarray(b[0, 8:]))


def test_prefill_then_verify_matches_full(params):
    seq = rand_tokens(30, 2)
    padded = jnp.zeros((TINY.maxlen,), jnp.int32).at[:30].set(seq)
    (cache,) = jax.jit(lambda f, t, n: prefill(TINY, True, f, t, n))(
        params, padded, jnp.int32(20))
    g = 5
    toks = seq[19:25]
    dists, _ = jax.jit(lambda *a: verify_block(TINY, g, True, *a))(
        params, cache, toks, jnp.int32(19), jnp.float32(1.0), jnp.float32(1.0))
    full, _ = forward(TINY, params, seq[None, :25])
    for i in range(g + 1):
        ref = adjust_dist(full[0, 19 + i], 1.0, 1.0)
        np.testing.assert_allclose(np.asarray(dists[i]), np.asarray(ref), atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(temp=st.sampled_from([0.7, 1.0, 1.4]), p=st.sampled_from([0.5, 0.9, 0.95, 1.0]),
       seed=st.integers(0, 1000))
def test_adjust_dist_is_distribution(temp, p, seed):
    logits = jnp.asarray(np.random.RandomState(seed).randn(vocab.VOCAB), jnp.float32)
    d = adjust_dist(logits, temp, p)
    total = float(jnp.sum(d))
    assert abs(total - 1.0) < 1e-5
    assert float(jnp.min(d)) >= 0.0
    # argmax survives any p
    assert float(d[int(jnp.argmax(logits))]) > 0.0


def test_adjust_dist_truncates_tail():
    logits = jnp.asarray([10.0, 9.0, 0.0, -5.0] + [-10.0] * 28, jnp.float32)
    d = adjust_dist(logits, 1.0, 0.9)
    assert float(jnp.sum(d > 0)) <= 3


def test_sample_from_dist_inverse_cdf():
    d = jnp.asarray([0.25, 0.25, 0.5], jnp.float32)
    assert int(sample_from_dist(d, jnp.float32(0.1))) == 0
    assert int(sample_from_dist(d, jnp.float32(0.3))) == 1
    assert int(sample_from_dist(d, jnp.float32(0.99))) == 2


def test_generate_block_candidates_and_dists(params):
    seq = rand_tokens(10, 3)
    padded = jnp.zeros((TINY.maxlen,), jnp.int32).at[:10].set(seq)
    (cache,) = jax.jit(lambda f, t, n: prefill(TINY, True, f, t, n))(
        params, padded, jnp.int32(10))
    c, g = 3, 5
    feed = jnp.zeros((g + 1,), jnp.int32).at[0].set(seq[9])
    u = jnp.asarray(np.random.RandomState(4).rand(c, g), jnp.float32)
    toks, dists, cache2 = jax.jit(lambda *a: generate_block(TINY, c, g, True, *a))(
        params, cache, feed, jnp.int32(1), jnp.int32(9), u,
        jnp.float32(1.0), jnp.float32(0.95))
    assert toks.shape == (c, g)
    assert dists.shape == (c, g, TINY.vocab)
    sums = np.asarray(dists.sum(-1))
    np.testing.assert_allclose(sums, 1.0, atol=1e-4)
    # each sampled token has nonzero prob in its own dist
    for ci in range(c):
        for gi in range(g):
            assert float(dists[ci, gi, int(toks[ci, gi])]) > 0.0
    assert cache2.shape == cache.shape


def test_score_seq_matches_forward(params):
    seq = rand_tokens(16, 5)
    padded = jnp.zeros((TINY.maxlen,), jnp.int32).at[:16].set(seq)
    (nll,) = jax.jit(lambda f, t, n: score_seq(TINY, f, t, n))(params, padded, jnp.int32(16))
    full, _ = forward(TINY, params, seq[None])
    lp = jax.nn.log_softmax(full[0], -1)
    ref = -np.asarray(lp)[np.arange(15), np.asarray(seq)[1:]]
    np.testing.assert_allclose(np.asarray(nll[1:16]), ref, rtol=1e-4, atol=1e-5)
    assert float(nll[0]) == 0.0
    np.testing.assert_allclose(np.asarray(nll[16:]), 0.0)


def test_embed_masks_padding(params):
    seq = rand_tokens(8, 6)
    padded = jnp.zeros((TINY.maxlen,), jnp.int32).at[:8].set(seq)
    (e1,) = jax.jit(lambda f, t, n: embed_seq(TINY, f, t, n))(params, padded, jnp.int32(8))
    # changing padding content must not change the embedding
    padded2 = padded.at[20].set(7)
    (e2,) = jax.jit(lambda f, t, n: embed_seq(TINY, f, t, n))(params, padded2, jnp.int32(8))
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-6)


def test_draft_target_configs_build():
    for cfg in (DRAFT, TARGET):
        p = init_params(cfg, jax.random.PRNGKey(1))
        assert p.shape[0] == cfg.n_params()
